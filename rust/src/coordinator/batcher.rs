//! Dynamic batching policy: collect requests per operator until the batch
//! is full or the oldest request's deadline expires (vLLM-style continuous
//! batching, simplified to the matvec setting).
//!
//! Since PR 3 the "full" threshold is **per operator**: the router passes
//! each [`Batcher::add`] call a limit resolved from the operator's
//! [`CostProfile`](crate::engine::CostProfile) by [`target_batch`] —
//! batches grow until the plan's fixed operand traffic is amortized, and
//! are capped by the execution-latency deadline and by the arena
//! footprint the batch would pin (the zero-alloc invariant from PR 1).
//! A fixed-size deployment simply passes the same limit for every call.
//!
//! Since PR 6 batching is additionally **traffic-class-aware**: requests
//! carry a [`QosClass`](super::QosClass) whose deadline budget tightens
//! (interactive) or widens (bulk) the latency term of the adaptive target
//! ([`target_batch_for_class`]) and caps how long a partial batch may
//! wait for company ([`Batcher::add_with_timeout`]). The router keys
//! batches by `(operator, class)`, so an interactive request never waits
//! behind a bulk batch filling up.
//!
//! **Flush order is deterministic.** Pending batches live in an
//! insertion-ordered list, not a hash map: [`Batcher::take_expired`] and
//! [`Batcher::drain`] emit batches oldest-key-first (the order the keys
//! first went pending), identically on every run. The pre-PR 10 `HashMap`
//! storage iterated in `RandomState` order, so timeout/shutdown flushes
//! dispatched in a different order each process — harmless for payload
//! correctness but a per-run perturbation of dispatch timing, and exactly
//! the pattern `scripts/lint_invariants.py` now rejects in serving
//! modules. Lookups are a linear scan, which is fine at router scale: the
//! live key set is (operators × 3 QoS classes) and flushing removes keys
//! continuously.

use super::QosClass;
use crate::engine::{footprint_for_elem, CostProfile};
use std::time::{Duration, Instant};

/// When to flush a partial batch.
#[derive(Clone, Debug)]
pub struct BatchPolicy {
    /// Default flush threshold for operators without a cost profile.
    pub max_batch: usize,
    /// Deadline before a partial batch is flushed.
    pub timeout: Duration,
}

/// Knobs of the plan-aware batch sizing model (see [`target_batch`]).
#[derive(Clone, Debug)]
pub struct AdaptiveBatchConfig {
    /// β in the model cost `flops + β·bytes` (same machine knob as
    /// [`PlanConfig::bytes_per_flop_weight`](crate::engine::PlanConfig)).
    pub beta: f64,
    /// ε — tolerated share of a batch's cost spent on the plan's fixed
    /// operand traffic. Smaller ε ⇒ wider batches.
    pub overhead_frac: f64,
    /// Nominal execution rate in model-cost units per nanosecond
    /// (≈ GFLOP/s for β = 0; deliberately conservative).
    pub cost_rate_per_ns: f64,
    /// Cap on the modeled execution time of one batch — bounds the
    /// latency a request can pay for riding in a wide batch.
    pub latency_cap: Duration,
    /// Cap on the arena ping-pong footprint a batch may pin
    /// (`2 × elem_bytes × max_dim × b` bytes — the profile's element
    /// width, 8 for f64 plans and 4 for f32).
    pub max_arena_bytes: usize,
    /// Hard ceiling regardless of what the model asks for.
    pub max_batch: usize,
}

impl Default for AdaptiveBatchConfig {
    fn default() -> Self {
        AdaptiveBatchConfig {
            beta: 0.25,
            overhead_frac: 0.02,
            cost_rate_per_ns: 1.0,
            latency_cap: Duration::from_millis(1),
            max_arena_bytes: 4 << 20,
            max_batch: 512,
        }
    }
}

/// Pick a per-operator target batch width from its [`CostProfile`].
///
/// The model: a `b`-column batch costs `fixed + b·col` where
/// `fixed = β·fixed_bytes` (operands streamed once) and
/// `col = flops_per_col + β·bytes_per_col`. The target is the smallest
/// `b` whose fixed-cost share is at most `ε` — wide enough to amortize
/// the plan, no wider — clamped by three caps:
///
/// 1. **latency**: modeled batch execution time stays under
///    `latency_cap` at the configured `cost_rate_per_ns`;
/// 2. **arena**: the batch's ping-pong scratch footprint
///    (`2·elem_bytes·max_dim·b` — the profile's own element width, so an
///    f32 plan batches twice as wide under the same cap) stays under
///    `max_arena_bytes`, so adaptive sizing can never silently break the
///    zero-alloc steady state;
/// 3. the hard `max_batch` ceiling.
pub fn target_batch(p: &CostProfile, cfg: &AdaptiveBatchConfig) -> usize {
    let col = p.col_cost(cfg.beta).max(1.0);
    let fixed = p.fixed_cost(cfg.beta);
    let b_amort = (fixed / (cfg.overhead_frac.max(1e-9) * col)).ceil() as usize;
    let budget = cfg.latency_cap.as_nanos() as f64 * cfg.cost_rate_per_ns;
    let b_latency = (((budget - fixed) / col).floor().max(1.0)) as usize;
    let per_col = footprint_for_elem(p.max_dim.max(1), p.elem_bytes);
    let b_arena = (cfg.max_arena_bytes / per_col).max(1);
    b_amort.clamp(1, b_latency.min(b_arena).min(cfg.max_batch.max(1)))
}

/// [`target_batch`] with the latency-deadline term driven by a traffic
/// class: half the class's deadline budget (see
/// [`QosClass::deadline_budget`]) replaces `cfg.latency_cap`, leaving the
/// other half for queueing and accumulation. [`QosClass::Standard`]'s
/// budget is `2 × latency_cap`, so the standard class reproduces
/// [`target_batch`] exactly; interactive targets are never wider, bulk
/// targets never narrower (both still bounded by the arena-footprint cap
/// and the hard ceiling — QoS can stretch the deadline, not the
/// zero-alloc invariant).
pub fn target_batch_for_class(
    p: &CostProfile,
    cfg: &AdaptiveBatchConfig,
    class: QosClass,
) -> usize {
    let cfg_c = AdaptiveBatchConfig {
        latency_cap: class.deadline_budget(cfg.latency_cap) / 2,
        ..cfg.clone()
    };
    target_batch(p, &cfg_c)
}

/// One key's accumulating batch: requests, first-insert time, and the
/// tightest flush timeout any of its requests asked for.
struct PendingEntry<R> {
    reqs: Vec<R>,
    t0: Instant,
    timeout: Duration,
}

/// Accumulates requests per key; generic over the key (the coordinator
/// router keys by `(operator, QosClass)`) and the request type so it is
/// unit-testable without spinning up the full coordinator. Keys are held
/// in first-insertion order — see the module docs on deterministic flush
/// order.
pub struct Batcher<K, R> {
    policy: BatchPolicy,
    pending: Vec<(K, PendingEntry<R>)>,
}

impl<K: Eq + Clone, R> Batcher<K, R> {
    pub fn new(policy: BatchPolicy) -> Self {
        Batcher { policy, pending: Vec::new() }
    }

    /// Add a request under `key`; returns the key's batch once `limit`
    /// requests have accumulated. `limit` is resolved per operator by the
    /// router ([`target_batch`] under adaptive sizing, the policy default
    /// otherwise) and re-read on every call, so a registry swap that
    /// changes an operator's plan takes effect on the very next request.
    ///
    /// The returned batch is the key's **entire accumulation**. When a
    /// swap just *lowered* the limit below what had already accumulated,
    /// the old accumulation flushes as one unit — the router splits it
    /// into `limit`-sized jobs downstream, so the arena-footprint cap
    /// behind an adaptive limit still holds — and the key starts fresh,
    /// re-resolving the limit on its next add. (Leaving a surplus pending
    /// here instead, as this method did before PR 6, pinned the flushed
    /// chunk's stale deadline on the survivors: `next_deadline_in` went
    /// to zero and the router span in a hot poll loop until the surplus
    /// dribbled out.)
    pub fn add(&mut self, key: K, r: R, limit: usize) -> Option<(K, Vec<R>)> {
        let timeout = self.policy.timeout;
        self.add_with_timeout(key, r, limit, timeout)
    }

    /// [`Batcher::add`] with a per-request flush-timeout cap: the entry
    /// keeps the tightest timeout any of its requests carried, so one
    /// interactive-deadline request in a batch pulls the whole batch's
    /// flush forward. `timeout` is clamped to the policy timeout by the
    /// router (a request can tighten the deadline, never extend it).
    pub fn add_with_timeout(
        &mut self,
        key: K,
        r: R,
        limit: usize,
        timeout: Duration,
    ) -> Option<(K, Vec<R>)> {
        let limit = limit.max(1);
        let idx = match self.pending.iter().position(|(k, _)| *k == key) {
            Some(i) => i,
            None => {
                self.pending.push((
                    key.clone(),
                    PendingEntry { reqs: Vec::new(), t0: Instant::now(), timeout },
                ));
                self.pending.len() - 1
            }
        };
        let entry = &mut self.pending[idx].1;
        entry.timeout = entry.timeout.min(timeout);
        entry.reqs.push(r);
        if entry.reqs.len() >= limit {
            // `Vec::remove` keeps the survivors' insertion order intact.
            let (key, entry) = self.pending.remove(idx);
            Some((key, entry.reqs))
        } else {
            None
        }
    }

    /// [`Batcher::add`] at the policy's default threshold.
    pub fn add_default(&mut self, key: K, r: R) -> Option<(K, Vec<R>)> {
        let limit = self.policy.max_batch;
        self.add(key, r, limit)
    }

    /// Time until the earliest pending batch expires (None if idle).
    pub fn next_deadline_in(&self) -> Option<Duration> {
        self.pending
            .iter()
            .map(|(_, e)| e.timeout.saturating_sub(e.t0.elapsed()))
            .min()
    }

    /// Remove and return every batch older than its flush timeout, in
    /// key-insertion order (deterministic run to run).
    pub fn take_expired(&mut self) -> Vec<(K, Vec<R>)> {
        let mut out = Vec::new();
        let mut i = 0;
        while i < self.pending.len() {
            if self.pending[i].1.t0.elapsed() >= self.pending[i].1.timeout {
                let (k, e) = self.pending.remove(i);
                out.push((k, e.reqs));
            } else {
                i += 1;
            }
        }
        out
    }

    /// Flush everything (shutdown), in key-insertion order.
    pub fn drain(&mut self) -> Vec<(K, Vec<R>)> {
        std::mem::take(&mut self.pending)
            .into_iter()
            .map(|(k, e)| (k, e.reqs))
            .collect()
    }

    /// Number of pending (unflushed) requests.
    pub fn pending_len(&self) -> usize {
        self.pending.iter().map(|(_, e)| e.reqs.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{ApplyPlan, PlanConfig};

    fn policy(max: usize, ms: u64) -> BatchPolicy {
        BatchPolicy { max_batch: max, timeout: Duration::from_millis(ms) }
    }

    #[test]
    fn flushes_when_full() {
        let mut b: Batcher<String, u32> = Batcher::new(policy(3, 1000));
        assert!(b.add_default("a".into(), 1).is_none());
        assert!(b.add_default("a".into(), 2).is_none());
        let (k, reqs) = b.add_default("a".into(), 3).expect("should flush at max");
        assert_eq!(k, "a");
        assert_eq!(reqs, vec![1, 2, 3]);
        assert_eq!(b.pending_len(), 0);
    }

    #[test]
    fn per_key_limits_override_the_policy_default() {
        let mut b: Batcher<String, u32> = Batcher::new(policy(100, 1000));
        assert!(b.add("a".into(), 1, 2).is_none());
        let (k, reqs) = b.add("a".into(), 2, 2).expect("per-key limit of 2");
        assert_eq!(k, "a");
        assert_eq!(reqs, vec![1, 2]);
        // A zero limit is treated as 1, never as "never flush".
        let (_, reqs) = b.add("z".into(), 9, 0).expect("limit 0 clamps to 1");
        assert_eq!(reqs, vec![9]);
    }

    #[test]
    fn lowered_limit_flushes_the_old_accumulation_and_re_resolves() {
        // Regression (PR 6): a key whose per-operator limit was lowered by
        // a swap while a partial batch was pending must flush the *old*
        // accumulation in one unit (the router splits it into limit-sized
        // jobs — see the coordinator's never-exceeds-arena test) and then
        // re-resolve the limit on the next add. The pre-fix behavior left
        // a surplus pending under the flushed chunk's stale deadline,
        // driving next_deadline_in to zero and the router into a hot poll.
        let mut b: Batcher<String, u32> = Batcher::new(policy(100, 1000));
        for i in 0..5 {
            assert!(b.add("a".into(), i, 10).is_none());
        }
        // A swap lowered the operator's target to 2: the next add flushes
        // everything that had accumulated under the old limit.
        let (_, reqs) = b.add("a".into(), 5, 2).expect("flush the old accumulation");
        assert_eq!(reqs, vec![0, 1, 2, 3, 4, 5]);
        // The key started fresh: no surplus, no stale deadline.
        assert_eq!(b.pending_len(), 0);
        assert!(b.next_deadline_in().is_none(), "stale entry survived the flush");
        // The next adds run at the re-resolved limit.
        assert!(b.add("a".into(), 6, 2).is_none());
        let (_, reqs) = b.add("a".into(), 7, 2).expect("fresh batch at the new limit");
        assert_eq!(reqs, vec![6, 7]);
    }

    #[test]
    fn keys_are_batched_separately() {
        let mut b: Batcher<String, u32> = Batcher::new(policy(2, 1000));
        assert!(b.add_default("a".into(), 1).is_none());
        assert!(b.add_default("b".into(), 2).is_none());
        assert_eq!(b.pending_len(), 2);
        let (k, reqs) = b.add_default("a".into(), 3).unwrap();
        assert_eq!(k, "a");
        assert_eq!(reqs, vec![1, 3]);
        assert_eq!(b.pending_len(), 1);
    }

    #[test]
    fn class_keys_batch_separately_per_class() {
        // The router keys by (operator, class): interactive requests never
        // wait behind a bulk batch filling up.
        let mut b: Batcher<(String, QosClass), u32> = Batcher::new(policy(2, 1000));
        assert!(b.add(("op".into(), QosClass::Interactive), 1, 2).is_none());
        assert!(b.add(("op".into(), QosClass::Bulk), 2, 2).is_none());
        let (k, reqs) = b.add(("op".into(), QosClass::Interactive), 3, 2).unwrap();
        assert_eq!(k, ("op".to_string(), QosClass::Interactive));
        assert_eq!(reqs, vec![1, 3]);
        assert_eq!(b.pending_len(), 1);
    }

    #[test]
    fn expiry_flushes_partial_batches() {
        let mut b: Batcher<String, u32> = Batcher::new(policy(100, 5));
        b.add_default("a".into(), 1);
        assert!(b.take_expired().is_empty());
        std::thread::sleep(Duration::from_millis(8));
        let expired = b.take_expired();
        assert_eq!(expired.len(), 1);
        assert_eq!(expired[0].1, vec![1]);
    }

    #[test]
    fn per_request_timeout_tightens_the_entry_deadline() {
        // One interactive-deadline request pulls the whole batch's flush
        // forward; a later laxer request cannot push it back.
        let mut b: Batcher<String, u32> = Batcher::new(policy(100, 1000));
        b.add_with_timeout("a".into(), 1, 100, Duration::from_millis(1000));
        b.add_with_timeout("a".into(), 2, 100, Duration::from_millis(5));
        b.add_with_timeout("a".into(), 3, 100, Duration::from_millis(1000));
        assert!(b.next_deadline_in().unwrap() <= Duration::from_millis(5));
        std::thread::sleep(Duration::from_millis(8));
        let expired = b.take_expired();
        assert_eq!(expired.len(), 1);
        assert_eq!(expired[0].1, vec![1, 2, 3]);
    }

    #[test]
    fn deadline_reporting() {
        let mut b: Batcher<String, u32> = Batcher::new(policy(10, 50));
        assert!(b.next_deadline_in().is_none());
        b.add_default("a".into(), 1);
        let d = b.next_deadline_in().unwrap();
        assert!(d <= Duration::from_millis(50));
    }

    #[test]
    fn drain_returns_everything() {
        let mut b: Batcher<String, u32> = Batcher::new(policy(10, 1000));
        b.add_default("a".into(), 1);
        b.add_default("b".into(), 2);
        let mut all = b.drain();
        all.sort_by(|x, y| x.0.cmp(&y.0));
        assert_eq!(all.len(), 2);
        assert_eq!(b.pending_len(), 0);
    }

    #[test]
    fn flush_order_is_key_insertion_order_and_deterministic() {
        // PR 10 regression: `pending` used to be a HashMap, so timeout
        // and shutdown flushes walked the keys in RandomState order —
        // different every process. Pin the contract: `drain` and
        // `take_expired` emit batches in first-insertion key order, and a
        // mid-stream full-batch flush does not disturb the survivors'
        // order.
        let keys = ["gamma", "alpha", "beta", "delta"];
        let mut b: Batcher<String, u32> = Batcher::new(policy(100, 1000));
        for (i, k) in keys.iter().enumerate() {
            b.add_default((*k).into(), i as u32);
        }
        let drained: Vec<String> = b.drain().into_iter().map(|(k, _)| k).collect();
        assert_eq!(drained, keys.map(String::from).to_vec());

        // take_expired: same order, and flushing "alpha" at its limit
        // first must leave gamma/beta/delta in insertion order.
        let mut b: Batcher<String, u32> = Batcher::new(policy(100, 0));
        for (i, k) in keys.iter().enumerate() {
            b.add((*k).into(), i as u32, 10);
        }
        let flushed = b.add("alpha".into(), 9, 2).expect("alpha at its limit");
        assert_eq!(flushed.0, "alpha");
        let expired: Vec<String> = b.take_expired().into_iter().map(|(k, _)| k).collect();
        assert_eq!(expired, vec!["gamma", "beta", "delta"]);
        assert_eq!(b.pending_len(), 0);
    }

    #[test]
    fn target_amortizes_fixed_cost() {
        let cfg = AdaptiveBatchConfig::default();
        let f = crate::transforms::hadamard_faust(256);
        let p = ApplyPlan::compile(&f, &PlanConfig::default()).profile();
        let t = target_batch(&p, &cfg);
        // The fixed share at the target is at most ε (unless a cap bit).
        let col = p.col_cost(cfg.beta);
        let fixed = p.fixed_cost(cfg.beta);
        assert!(t >= 1 && t <= cfg.max_batch);
        assert!(
            fixed / (t as f64 * col) <= cfg.overhead_frac * 1.01 || t == cfg.max_batch,
            "t={t} leaves fixed share {}",
            fixed / (t as f64 * col)
        );
        // A heavier operator (more fixed bytes per column) wants wider
        // batches; an expensive-per-column one saturates the deadline.
        let dense = crate::engine::CostProfile::dense(256, 256);
        let td = target_batch(&dense, &cfg);
        assert!(td >= 1);
    }

    #[test]
    fn target_respects_latency_and_arena_caps() {
        let f = crate::transforms::hadamard_faust(64);
        let p = ApplyPlan::compile(&f, &PlanConfig::default()).profile();
        // Tight latency cap pins the batch low.
        let tight = AdaptiveBatchConfig {
            latency_cap: Duration::from_nanos(1),
            ..AdaptiveBatchConfig::default()
        };
        assert_eq!(target_batch(&p, &tight), 1);
        // Tight arena cap bounds the pinned footprint.
        let small = AdaptiveBatchConfig {
            max_arena_bytes: footprint_for_elem(p.max_dim, p.elem_bytes) * 4,
            ..AdaptiveBatchConfig::default()
        };
        let t = target_batch(&p, &small);
        assert!(footprint_for_elem(p.max_dim * t, p.elem_bytes) <= small.max_arena_bytes);
        // Hard ceiling always wins.
        let capped = AdaptiveBatchConfig { max_batch: 3, ..AdaptiveBatchConfig::default() };
        assert!(target_batch(&p, &capped) <= 3);
    }

    #[test]
    fn f32_profiles_batch_wider_under_an_arena_bound_cap() {
        // Same operator, same cap: when the arena term binds, the f32
        // plan's 4-byte columns fit twice as many per batch.
        let f = crate::transforms::hadamard_faust(64);
        let plan = ApplyPlan::compile(&f, &PlanConfig::default());
        let p64 = plan.profile();
        let p32 = plan.to_f32().profile();
        assert_eq!(p32.elem_bytes, 4);
        let cfg = AdaptiveBatchConfig {
            max_arena_bytes: footprint_for_elem(p64.max_dim, 8) * 4,
            overhead_frac: 1e-9, // force b_amort huge so the caps decide
            ..AdaptiveBatchConfig::default()
        };
        let t64 = target_batch(&p64, &cfg);
        let t32 = target_batch(&p32, &cfg);
        assert_eq!(t64, 4);
        assert_eq!(t32, 8, "f32 batches should double under the arena cap");
    }

    #[test]
    fn class_targets_order_with_their_deadline_budgets() {
        let cfg = AdaptiveBatchConfig::default();
        let f = crate::transforms::hadamard_faust(256);
        let p = ApplyPlan::compile(&f, &PlanConfig::default()).profile();
        let ti = target_batch_for_class(&p, &cfg, QosClass::Interactive);
        let ts = target_batch_for_class(&p, &cfg, QosClass::Standard);
        let tb = target_batch_for_class(&p, &cfg, QosClass::Bulk);
        // Standard reproduces the class-less model exactly; interactive
        // is never wider, bulk never narrower.
        assert_eq!(ts, target_batch(&p, &cfg));
        assert!(ti <= ts && ts <= tb, "class targets out of order: {ti} {ts} {tb}");
        // Bulk's wide budget still cannot stretch the arena cap.
        let small = AdaptiveBatchConfig {
            max_arena_bytes: footprint_for_elem(p.max_dim, p.elem_bytes) * 4,
            ..AdaptiveBatchConfig::default()
        };
        let t = target_batch_for_class(&p, &small, QosClass::Bulk);
        assert!(footprint_for_elem(p.max_dim * t, p.elem_bytes) <= small.max_arena_bytes);
    }
}
