//! Dynamic batching policy: collect requests per operator until the batch
//! is full or the oldest request's deadline expires (vLLM-style continuous
//! batching, simplified to the matvec setting).

use std::collections::HashMap;
use std::time::{Duration, Instant};

/// When to flush a partial batch.
#[derive(Clone, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub timeout: Duration,
}

/// Accumulates requests per key; generic so it is unit-testable without
/// spinning up the full coordinator.
pub struct Batcher<R> {
    policy: BatchPolicy,
    pending: HashMap<String, (Vec<R>, Instant)>,
}

impl<R> Batcher<R> {
    pub fn new(policy: BatchPolicy) -> Self {
        Batcher { policy, pending: HashMap::new() }
    }

    /// Add a request under `key`; returns a full batch if the size
    /// threshold was reached.
    pub fn add(&mut self, key: String, r: R) -> Option<(String, Vec<R>)> {
        let entry = self
            .pending
            .entry(key.clone())
            .or_insert_with(|| (Vec::new(), Instant::now()));
        entry.0.push(r);
        if entry.0.len() >= self.policy.max_batch {
            let (reqs, _) = self.pending.remove(&key).unwrap();
            Some((key, reqs))
        } else {
            None
        }
    }

    /// Time until the earliest pending batch expires (None if idle).
    pub fn next_deadline_in(&self) -> Option<Duration> {
        self.pending
            .values()
            .map(|(_, t0)| {
                let elapsed = t0.elapsed();
                self.policy.timeout.saturating_sub(elapsed)
            })
            .min()
    }

    /// Remove and return every batch older than the timeout.
    pub fn take_expired(&mut self) -> Vec<(String, Vec<R>)> {
        let timeout = self.policy.timeout;
        let expired: Vec<String> = self
            .pending
            .iter()
            .filter(|(_, (_, t0))| t0.elapsed() >= timeout)
            .map(|(k, _)| k.clone())
            .collect();
        expired
            .into_iter()
            .map(|k| {
                let (reqs, _) = self.pending.remove(&k).unwrap();
                (k, reqs)
            })
            .collect()
    }

    /// Flush everything (shutdown).
    pub fn drain(&mut self) -> Vec<(String, Vec<R>)> {
        self.pending
            .drain()
            .map(|(k, (reqs, _))| (k, reqs))
            .collect()
    }

    /// Number of pending (unflushed) requests.
    pub fn pending_len(&self) -> usize {
        self.pending.values().map(|(v, _)| v.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy(max: usize, ms: u64) -> BatchPolicy {
        BatchPolicy { max_batch: max, timeout: Duration::from_millis(ms) }
    }

    #[test]
    fn flushes_when_full() {
        let mut b: Batcher<u32> = Batcher::new(policy(3, 1000));
        assert!(b.add("a".into(), 1).is_none());
        assert!(b.add("a".into(), 2).is_none());
        let (k, reqs) = b.add("a".into(), 3).expect("should flush at max");
        assert_eq!(k, "a");
        assert_eq!(reqs, vec![1, 2, 3]);
        assert_eq!(b.pending_len(), 0);
    }

    #[test]
    fn keys_are_batched_separately() {
        let mut b: Batcher<u32> = Batcher::new(policy(2, 1000));
        assert!(b.add("a".into(), 1).is_none());
        assert!(b.add("b".into(), 2).is_none());
        assert_eq!(b.pending_len(), 2);
        let (k, reqs) = b.add("a".into(), 3).unwrap();
        assert_eq!(k, "a");
        assert_eq!(reqs, vec![1, 3]);
        assert_eq!(b.pending_len(), 1);
    }

    #[test]
    fn expiry_flushes_partial_batches() {
        let mut b: Batcher<u32> = Batcher::new(policy(100, 5));
        b.add("a".into(), 1);
        assert!(b.take_expired().is_empty());
        std::thread::sleep(Duration::from_millis(8));
        let expired = b.take_expired();
        assert_eq!(expired.len(), 1);
        assert_eq!(expired[0].1, vec![1]);
    }

    #[test]
    fn deadline_reporting() {
        let mut b: Batcher<u32> = Batcher::new(policy(10, 50));
        assert!(b.next_deadline_in().is_none());
        b.add("a".into(), 1);
        let d = b.next_deadline_in().unwrap();
        assert!(d <= Duration::from_millis(50));
    }

    #[test]
    fn drain_returns_everything() {
        let mut b: Batcher<u32> = Batcher::new(policy(10, 1000));
        b.add("a".into(), 1);
        b.add("b".into(), 2);
        let mut all = b.drain();
        all.sort_by(|x, y| x.0.cmp(&y.0));
        assert_eq!(all.len(), 2);
        assert_eq!(b.pending_len(), 0);
    }
}
