//! Online learning inside the serving loop (ROADMAP item i): an
//! [`OnlineLearner`] feeds observed columns into a streaming
//! [`OnlinePalm`] factorization and continuously publishes improved
//! generations through the live [`Registry`] — the serving system keeps
//! learning while it serves.
//!
//! The split of responsibilities:
//!
//! - [`crate::palm::online`] owns the *math*: the per-column surrogate,
//!   the forgetting factor, the weighted mini-batch sweep, and its
//!   bitwise online/batch boundary contract.
//! - This module owns the *policy*: mini-batch assembly from a raw
//!   observation stream ([`OnlineLearnConfig::batch_cols`]), the swap
//!   cadence ([`OnlineLearnConfig::swap_every`] with an
//!   improvement-gated publish that re-scores the incumbent generation
//!   against the current surrogate, so a *worse* candidate is never
//!   swapped in yet a stale incumbent never blocks tracking), and the
//!   drift metrics
//!   ([`MetricsSnapshot::online_batches`] / `online_cols` /
//!   `online_swaps` / `online_rel_err`).
//! - [`OnlineLearnerTask`] is the deployment shape: a dedicated thread
//!   consuming a bounded observation channel, so learning shares the
//!   machine with serving without ever stalling a request — swaps go
//!   through [`Registry::swap_epoch`], which drains old generations on
//!   their `Arc`s exactly like every other swap.
//!
//! # Determinism
//!
//! Observations are folded in channel/arrival order, mini-batches cut at
//! fixed [`OnlineLearnConfig::batch_cols`] boundaries, and every sweep
//! runs thread-invariant ctx kernels — so a fixed observation stream
//! reproduces bitwise-identical factors, swap decisions and epochs at
//! any thread count. With [`CoordinatorConfig::online`] `None` (the
//! default) none of this code runs and the f64 serving path is bitwise
//! identical to the pre-online coordinator.
//!
//! [`CoordinatorConfig::online`]: super::CoordinatorConfig::online
//! [`MetricsSnapshot::online_batches`]: super::MetricsSnapshot::online_batches

use super::{BatchOp, Metrics, Registry};
use crate::engine::ExecCtx;
use crate::faust::Faust;
use crate::palm::online::{OnlinePalm, OnlineStep};
use crate::palm::FactorState;
use std::sync::mpsc::{sync_channel, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;

/// Swap-cadence policy for an [`OnlineLearner`] (the coordinator-level
/// half of online learning; the PALM-level half is
/// [`crate::palm::online::OnlineConfig`]).
#[derive(Clone, Debug)]
pub struct OnlineLearnConfig {
    /// Observed columns per mini-batch: the learner buffers incoming
    /// observations and runs one weighted sweep per full mini-batch.
    pub batch_cols: usize,
    /// Publish cadence: every `swap_every` mini-batches the learner
    /// considers an epoch swap (clamped to ≥ 1).
    pub swap_every: u64,
    /// Improvement gate: publish only when the sweep's relative error
    /// beats the last published generation's by more than this margin
    /// (`0.0` publishes on any strict improvement). The published
    /// generation is re-scored against the *current* surrogate at every
    /// cadence point ([`OnlinePalm::rel_err_of`]): under drift a
    /// generation that was excellent when it shipped goes stale, and a
    /// gate frozen at its error-at-publish would block every future
    /// swap. Keeps worse generations out of the registry while still
    /// tracking a moving operator.
    pub min_gain: f64,
}

impl Default for OnlineLearnConfig {
    fn default() -> Self {
        OnlineLearnConfig { batch_cols: 8, swap_every: 4, min_gain: 0.0 }
    }
}

/// Final accounting of one learner (returned by
/// [`OnlineLearnerTask::finish`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct OnlineLearnerReport {
    /// Mini-batches swept.
    pub batches: u64,
    /// Columns observed (with repetition).
    pub cols: u64,
    /// Generations published via [`Registry::swap_epoch`].
    pub swaps: u64,
    /// Relative error after the last sweep (`NaN` if none ran).
    pub rel_err: f64,
}

/// Streams observed columns into an [`OnlinePalm`] learner and
/// epoch-swaps improved generations into the [`Registry`] under the
/// [`OnlineLearnConfig`] cadence policy. Synchronous — drive it from
/// your own loop, or wrap it in an [`OnlineLearnerTask`] thread.
pub struct OnlineLearner {
    name: String,
    registry: Arc<Registry>,
    metrics: Arc<Metrics>,
    palm: OnlinePalm,
    cfg: OnlineLearnConfig,
    pending: Vec<(usize, Vec<f64>)>,
    batches: u64,
    swaps: u64,
    last_step: Option<OnlineStep>,
    /// The last published generation's factors (`None` until the first
    /// publish, so the first cadence hit always publishes). Kept so the
    /// gate can re-score it against the current surrogate.
    published: Option<FactorState>,
}

impl OnlineLearner {
    /// Learner for registry operator `name`, from an explicitly built
    /// [`OnlinePalm`] (cold, warm, or resumed from a store snapshot via
    /// [`OnlinePalm::from_parts`]). Prefer
    /// [`Coordinator::online_learner`](super::Coordinator::online_learner)
    /// on a running coordinator — it wires the registry, metrics and
    /// configured cadence for you.
    pub fn new(
        name: impl Into<String>,
        registry: Arc<Registry>,
        metrics: Arc<Metrics>,
        palm: OnlinePalm,
        cfg: OnlineLearnConfig,
    ) -> OnlineLearner {
        OnlineLearner {
            name: name.into(),
            registry,
            metrics,
            palm,
            cfg,
            pending: Vec::new(),
            batches: 0,
            swaps: 0,
            last_step: None,
            published: None,
        }
    }

    /// Buffer one observed column (`j`, payload). Sweeps run when a full
    /// mini-batch has accumulated — call [`OnlineLearner::try_step`].
    pub fn observe(&mut self, j: usize, col: Vec<f64>) {
        self.pending.push((j, col));
    }

    /// Columns buffered toward the next mini-batch.
    pub fn pending(&self) -> usize {
        self.pending.len()
    }

    /// A full mini-batch is buffered.
    pub fn ready(&self) -> bool {
        self.pending.len() >= self.cfg.batch_cols.max(1)
    }

    /// If a full mini-batch is buffered, run one sweep (and possibly an
    /// epoch swap, per the cadence policy). `publish` turns the learned
    /// factors into a servable operator — e.g.
    /// `|f| Arc::new(engine.op_batch_hint(f, batch)) as Arc<dyn BatchOp>`,
    /// the same shape as [`Registry::load_store`]'s publish hook.
    pub fn try_step(
        &mut self,
        ctx: &ExecCtx,
        publish: &dyn Fn(&Faust) -> Arc<dyn BatchOp>,
    ) -> Option<OnlineStep> {
        if !self.ready() {
            return None;
        }
        let take = self.cfg.batch_cols.max(1).min(self.pending.len());
        let rest = self.pending.split_off(take);
        let batch = std::mem::replace(&mut self.pending, rest);
        Some(self.step_batch(ctx, publish, batch))
    }

    /// Sweep whatever is buffered, full mini-batch or not (stream-end
    /// tail). `None` if nothing is buffered.
    pub fn flush(
        &mut self,
        ctx: &ExecCtx,
        publish: &dyn Fn(&Faust) -> Arc<dyn BatchOp>,
    ) -> Option<OnlineStep> {
        if self.pending.is_empty() {
            return None;
        }
        let batch = std::mem::take(&mut self.pending);
        Some(self.step_batch(ctx, publish, batch))
    }

    fn step_batch(
        &mut self,
        ctx: &ExecCtx,
        publish: &dyn Fn(&Faust) -> Arc<dyn BatchOp>,
        batch: Vec<(usize, Vec<f64>)>,
    ) -> OnlineStep {
        let step = self.palm.step(ctx, &batch);
        self.batches += 1;
        self.metrics.record_online_batch(batch.len() as u64);
        self.metrics.record_online_rel_err(step.rel_err);
        self.last_step = Some(step);
        if self.batches % self.cfg.swap_every.max(1) == 0 {
            self.publish_if_improved(ctx, publish);
        }
        step
    }

    /// Publish the current factors now iff they beat the last published
    /// generation by the configured margin (cadence-independent — the
    /// stream-end path). The bar is the published generation re-scored
    /// against the *current* surrogate, so under drift the gate tracks
    /// staleness instead of freezing at the old error-at-publish.
    /// Returns the new epoch on publish.
    pub fn publish_if_improved(
        &mut self,
        ctx: &ExecCtx,
        publish: &dyn Fn(&Faust) -> Arc<dyn BatchOp>,
    ) -> Option<u64> {
        let rel_err = self.last_step?.rel_err;
        let bar = self
            .published
            .as_ref()
            .map_or(f64::INFINITY, |st| self.palm.rel_err_of(ctx, st));
        if !(rel_err + self.cfg.min_gain < bar) {
            return None;
        }
        let f = self.palm.to_faust();
        match self.registry.swap_epoch(&self.name, publish(&f)) {
            Ok(epoch) => {
                self.metrics.record_online_swap();
                self.swaps += 1;
                self.published = Some(self.palm.state().clone());
                Some(epoch)
            }
            // Operator retired (or re-registered with another shape) out
            // from under the learner: keep learning, publish nothing.
            Err(_) => None,
        }
    }

    /// Operator this learner publishes to.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Generations published so far.
    pub fn swaps(&self) -> u64 {
        self.swaps
    }

    /// Relative error after the last sweep (`NaN` before the first).
    pub fn rel_err(&self) -> f64 {
        self.last_step.map_or(f64::NAN, |s| s.rel_err)
    }

    /// The underlying streaming learner — surrogate, weights and
    /// counters for store snapshots ([`crate::store::StoredLearner`]).
    pub fn palm(&self) -> &OnlinePalm {
        &self.palm
    }

    fn report(&self) -> OnlineLearnerReport {
        OnlineLearnerReport {
            batches: self.batches,
            cols: self.palm.cols_seen(),
            swaps: self.swaps,
            rel_err: self.rel_err(),
        }
    }
}

/// A background online-learning thread: feeds an [`OnlineLearner`] from
/// a bounded observation channel so the serving path never blocks on a
/// sweep. Observations are processed strictly in send order (one
/// consumer), preserving the determinism contract.
pub struct OnlineLearnerTask {
    tx: Option<SyncSender<(usize, Vec<f64>)>>,
    handle: Option<JoinHandle<OnlineLearnerReport>>,
}

impl OnlineLearnerTask {
    /// Spawn the learner thread (`faust-online-<op>`). `ctx` is the
    /// execution context sweeps run on — pass the serving engine's
    /// (`ApplyEngine::ctx()`) so learning shares the deployment's pool.
    /// `queue` bounds the observation channel (backpressure on the
    /// feeder, never on serving).
    pub fn spawn(
        mut learner: OnlineLearner,
        ctx: ExecCtx,
        publish: impl Fn(&Faust) -> Arc<dyn BatchOp> + Send + 'static,
        queue: usize,
    ) -> OnlineLearnerTask {
        let (tx, rx) = sync_channel::<(usize, Vec<f64>)>(queue.max(1));
        let handle = std::thread::Builder::new()
            .name(format!("faust-online-{}", learner.name()))
            .spawn(move || {
                while let Ok((j, col)) = rx.recv() {
                    learner.observe(j, col);
                    while learner.try_step(&ctx, &publish).is_some() {}
                }
                // Stream closed: sweep the tail, then give the final
                // generation one last (improvement-gated) publish.
                learner.flush(&ctx, &publish);
                learner.publish_if_improved(&ctx, &publish);
                learner.report()
            })
            .expect("spawn online learner");
        OnlineLearnerTask { tx: Some(tx), handle: Some(handle) }
    }

    /// Feed one observed column. Blocks only when the observation queue
    /// is full (the learner is behind); `false` once the task is gone.
    pub fn observe(&self, j: usize, col: Vec<f64>) -> bool {
        match &self.tx {
            Some(tx) => tx.send((j, col)).is_ok(),
            None => false,
        }
    }

    /// Close the stream, drain the tail, join the thread.
    pub fn finish(mut self) -> OnlineLearnerReport {
        drop(self.tx.take());
        match self.handle.take() {
            Some(h) => h.join().expect("online learner panicked"),
            None => OnlineLearnerReport::default(),
        }
    }
}

impl Drop for OnlineLearnerTask {
    fn drop(&mut self) {
        drop(self.tx.take());
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::{Coordinator, CoordinatorConfig};
    use super::*;
    use crate::linalg::Mat;
    use crate::palm::online::OnlineConfig;
    use crate::palm::PalmConfig;
    use crate::prox::Constraint;
    use crate::rng::Rng;

    fn publish_plain() -> impl Fn(&Faust) -> Arc<dyn BatchOp> + Send + 'static {
        |f: &Faust| Arc::new(f.clone()) as Arc<dyn BatchOp>
    }

    fn hadamard_stream(n: usize, passes: usize) -> Vec<(usize, Vec<f64>)> {
        let a = crate::transforms::hadamard(n);
        let mut s = Vec::with_capacity(n * passes);
        for _ in 0..passes {
            for j in 0..n {
                s.push((j, a.col(j)));
            }
        }
        s
    }

    #[test]
    fn learner_converges_and_swaps_through_a_live_coordinator() {
        let n = 8;
        let a = crate::transforms::hadamard(n);
        let coord = Coordinator::start(
            vec![("h".to_string(), Arc::new(a.clone()) as Arc<dyn BatchOp>)],
            CoordinatorConfig::online_learning(),
        );
        assert!(coord.online_config().is_some());
        let learner = coord
            .online_learner(
                "h",
                OnlinePalm::cold(
                    &[(n, n); 3],
                    OnlineConfig::new(PalmConfig::new(vec![Constraint::SpRowCol(2); 3], 1)),
                ),
            )
            .expect("online learning is on");
        let ctx = ExecCtx::new(1);
        let task = OnlineLearnerTask::spawn(learner, ctx, publish_plain(), 256);
        for (j, col) in hadamard_stream(n, 40) {
            assert!(task.observe(j, col));
        }
        let rep = task.finish();
        assert!(rep.batches > 0);
        assert_eq!(rep.cols, (n * 40) as u64);
        assert!(rep.swaps >= 3, "expected ≥3 online swaps, got {}", rep.swaps);
        assert!(rep.rel_err < 1e-3, "never converged: rel_err={}", rep.rel_err);
        // The served generation is now the learned FAμST — and it still
        // answers correctly.
        let client = coord.client();
        let mut rng = Rng::new(5);
        let x = rng.gauss_vec(n);
        let y = client.apply("h", x.clone()).unwrap();
        let want = a.matvec(&x);
        for i in 0..n {
            assert!((y[i] - want[i]).abs() < 1e-2, "served output drifted");
        }
        let snap = coord.shutdown();
        assert!(snap.swaps >= rep.swaps, "registry swaps must include online swaps");
        assert_eq!(snap.online_swaps, rep.swaps);
        assert_eq!(snap.online_cols, rep.cols);
        assert_eq!(snap.online_rel_err, rep.rel_err, "gauge holds the last sweep's error");
    }

    #[test]
    fn publish_is_improvement_gated() {
        // A learner whose error cannot improve (operator already exact,
        // min_gain pushed high) publishes exactly once.
        let n = 4;
        let a = crate::transforms::hadamard(n);
        let coord = Coordinator::start(
            vec![("h".to_string(), Arc::new(a.clone()) as Arc<dyn BatchOp>)],
            CoordinatorConfig::default(),
        );
        let metrics = Arc::new(Metrics::new());
        let mut learner = OnlineLearner::new(
            "h",
            coord.registry(),
            metrics.clone(),
            OnlinePalm::cold(
                &[(n, n); 2],
                OnlineConfig::new(PalmConfig::new(vec![Constraint::SpRowCol(2); 2], 1)),
            ),
            OnlineLearnConfig { batch_cols: n, swap_every: 1, min_gain: 10.0 },
        );
        let ctx = ExecCtx::new(1);
        let publish = publish_plain();
        for (j, col) in hadamard_stream(n, 10) {
            learner.observe(j, col);
            while learner.try_step(&ctx, &publish).is_some() {}
        }
        // min_gain = 10: only the first publish (vs ∞) can clear the bar.
        assert_eq!(learner.swaps(), 1);
        assert_eq!(metrics.snapshot().online_swaps, 1);
        assert_eq!(metrics.snapshot().online_batches, 10);
        coord.shutdown();
    }

    #[test]
    fn fixed_stream_is_bitwise_reproducible() {
        // Same observation stream, fresh learner ⇒ bitwise-identical
        // factors, λ, and swap count (the determinism contract).
        let n = 8;
        let stream = hadamard_stream(n, 12);
        let run = |threads: usize| {
            let coord = Coordinator::start(
                vec![(
                    "h".to_string(),
                    Arc::new(crate::transforms::hadamard(n)) as Arc<dyn BatchOp>,
                )],
                CoordinatorConfig::default(),
            );
            let mut learner = OnlineLearner::new(
                "h",
                coord.registry(),
                Arc::new(Metrics::new()),
                OnlinePalm::cold(
                    &[(n, n); 3],
                    OnlineConfig::new(PalmConfig::new(vec![Constraint::SpRowCol(2); 3], 1)),
                ),
                OnlineLearnConfig::default(),
            );
            let ctx = ExecCtx::new(threads);
            let publish = publish_plain();
            for (j, col) in &stream {
                learner.observe(*j, col.clone());
                while learner.try_step(&ctx, &publish).is_some() {}
            }
            let st = learner.palm().state().clone();
            let swaps = learner.swaps();
            coord.shutdown();
            (st, swaps)
        };
        let (st1, sw1) = run(1);
        let (st4, sw4) = run(4);
        assert_eq!(sw1, sw4, "swap decisions diverged across thread counts");
        assert_eq!(st1.lambda.to_bits(), st4.lambda.to_bits());
        for (p, q) in st1.mats.iter().zip(&st4.mats) {
            assert_eq!(p.data(), q.data(), "factor bits diverged");
        }
    }

    #[test]
    fn retired_operator_never_panics_the_learner() {
        let n = 4;
        let a = crate::transforms::hadamard(n);
        let coord = Coordinator::start(
            vec![("h".to_string(), Arc::new(a) as Arc<dyn BatchOp>)],
            CoordinatorConfig::default(),
        );
        let mut learner = OnlineLearner::new(
            "h",
            coord.registry(),
            Arc::new(Metrics::new()),
            OnlinePalm::cold(
                &[(n, n); 2],
                OnlineConfig::new(PalmConfig::new(vec![Constraint::SpRowCol(2); 2], 1)),
            ),
            OnlineLearnConfig { batch_cols: n, swap_every: 1, min_gain: 0.0 },
        );
        coord.registry().retire("h");
        let ctx = ExecCtx::new(1);
        let publish = publish_plain();
        for (j, col) in hadamard_stream(n, 3) {
            learner.observe(j, col);
            while learner.try_step(&ctx, &publish).is_some() {}
        }
        assert_eq!(learner.swaps(), 0, "publish to a retired op must be a quiet no-op");
        coord.shutdown();
    }

    #[test]
    fn drift_is_tracked_under_forgetting() {
        // The true operator is replaced mid-stream; with forgetting the
        // published generation re-fits the new one.
        let mut rng = Rng::new(41);
        let n = 6;
        let a0 = Mat::randn(n, n, &mut rng);
        let a1 = Mat::randn(n, n, &mut rng);
        let coord = Coordinator::start(
            vec![("m".to_string(), Arc::new(a0.clone()) as Arc<dyn BatchOp>)],
            CoordinatorConfig::default(),
        );
        let mut learner = OnlineLearner::new(
            "m",
            coord.registry(),
            Arc::new(Metrics::new()),
            OnlinePalm::cold(
                &[(n, n); 2],
                OnlineConfig::new(PalmConfig::new(
                    vec![Constraint::SpGlobal(n * n); 2],
                    1,
                ))
                .with_forgetting(0.5),
            ),
            OnlineLearnConfig { batch_cols: n, swap_every: 2, min_gain: 0.0 },
        );
        let ctx = ExecCtx::new(1);
        let publish = publish_plain();
        let mut feed = |learner: &mut OnlineLearner, a: &Mat, passes: usize| {
            for _ in 0..passes {
                for j in 0..n {
                    learner.observe(j, a.col(j));
                    while learner.try_step(&ctx, &publish).is_some() {}
                }
            }
        };
        feed(&mut learner, &a0, 30);
        let swaps_before_drift = learner.swaps();
        feed(&mut learner, &a1, 30);
        let f = learner.palm().to_faust();
        let (fresh, stale) = (f.relative_error_fro(&a1), f.relative_error_fro(&a0));
        assert!(fresh < stale, "learner stuck on the stale operator: {fresh} vs {stale}");
        // The staleness-aware gate keeps publishing after the operator
        // moved: the incumbent generation (fit to a0) re-scores badly on
        // the drifted surrogate, so re-fits to a1 clear the bar.
        assert!(
            learner.swaps() > swaps_before_drift,
            "gate froze after drift: {} swaps before, {} after",
            swaps_before_drift,
            learner.swaps()
        );
        coord.shutdown();
    }
}

/// Loom model of the learner's observe/finish channel protocol
/// (`cargo test --features loom-model --release loom_`). `std::sync::mpsc`
/// has no loom twin, so the model rebuilds the same bounded-queue
/// protocol — blocking bounded send, close-then-drain shutdown — on the
/// `engine::sync` primitives and proves the properties the production
/// channel is trusted for: no observation is lost or reordered across
/// `finish`, and neither side can hang on a lost wakeup.
#[cfg(all(test, feature = "loom-model"))]
mod loom_tests {
    use crate::engine::sync::{Condvar, Mutex};
    use loom::sync::Arc;
    use loom::thread;

    /// Bounded observe queue: capacity-1 ring + closed flag, one condvar
    /// on each side — the same shape `sync_channel(queue)` gives the
    /// learner task.
    struct ObserveQueue {
        buf: Mutex<(Vec<u32>, bool)>,
        can_send: Condvar,
        can_recv: Condvar,
    }

    impl ObserveQueue {
        fn new() -> Self {
            ObserveQueue {
                buf: Mutex::new((Vec::new(), false)),
                can_send: Condvar::new(),
                can_recv: Condvar::new(),
            }
        }

        /// Blocking bounded send (capacity 1) — backpressure on the
        /// feeder, exactly like `SyncSender::send`.
        fn observe(&self, v: u32) {
            let mut g = self.buf.lock().unwrap();
            while !g.0.is_empty() {
                g = self.can_send.wait(g).unwrap();
            }
            g.0.push(v);
            self.can_recv.notify_one();
        }

        /// Close the stream (the `finish` / drop-the-sender half).
        fn close(&self) {
            let mut g = self.buf.lock().unwrap();
            g.1 = true;
            self.can_recv.notify_one();
        }

        /// Blocking receive; `None` only once closed *and* drained — the
        /// learner's drain-the-tail-before-report contract.
        fn recv(&self) -> Option<u32> {
            let mut g = self.buf.lock().unwrap();
            loop {
                if let Some(v) = g.0.pop() {
                    self.can_send.notify_one();
                    return Some(v);
                }
                if g.1 {
                    return None;
                }
                g = self.can_recv.wait(g).unwrap();
            }
        }
    }

    /// Two observations through a full-at-one queue racing `close`: the
    /// learner must see both, in send order, then terminate. Loom flags
    /// any interleaving that hangs (lost wakeup) or drops the tail
    /// observation (close outrunning the drain).
    #[test]
    fn loom_observe_finish_loses_no_observations() {
        loom::model(|| {
            let q = Arc::new(ObserveQueue::new());
            let learner = {
                let q = q.clone();
                thread::spawn(move || {
                    let mut seen = Vec::new();
                    while let Some(v) = q.recv() {
                        seen.push(v);
                    }
                    seen
                })
            };
            q.observe(1);
            q.observe(2);
            q.close();
            let seen = learner.join().unwrap();
            assert_eq!(seen, vec![1, 2], "observation lost or reordered across finish");
        });
    }
}
