//! Proposition A.2 — projection onto sparse piecewise-constant matrices.
//!
//! Cells `C_i` partition (a subset of) the index set; feasible matrices are
//! constant on each cell, zero elsewhere, with at most `s` non-zero cells
//! and unit Frobenius norm. Circulant / Toeplitz / Hankel matrices with
//! prescribed diagonal sparsity and constant-by-row/column matrices are all
//! instances.
//!
//! Derivation note: with `ũ_i = Σ_{(m,n)∈C_i} u_mn`, the optimal support
//! keeps the `s` cells with largest `|ũ_i| / √|C_i|` and the optimal value
//! on a kept cell is `ã_i ∝ ũ_i / |C_i|`, normalized so `Σ |C_i| ã_i² = 1`.
//! (The closed form printed in the paper's Prop. A.2 normalizes correctly
//! but is only the exact maximizer when all kept cells have equal size; we
//! implement the true arg-max, which the paper's proof — via the change of
//! variables `b̃_i = √|C_i| ã_i` — actually establishes. A property test
//! below checks optimality against random feasible points.)

use super::sparsity::top_k_indices;
use crate::linalg::Mat;

/// A partition of (a subset of) the `rows × cols` index set into cells.
#[derive(Clone, Debug)]
pub struct CellPartition {
    rows: usize,
    cols: usize,
    /// `cell_of[e]` = cell id of flat index `e`, or `usize::MAX` if the
    /// entry must be zero (outside every cell).
    cell_of: Vec<usize>,
    /// Number of entries in each cell.
    sizes: Vec<usize>,
}

impl CellPartition {
    /// Build from a cell-id map (`usize::MAX` = forced zero).
    pub fn from_map(rows: usize, cols: usize, cell_of: Vec<usize>) -> Self {
        assert_eq!(cell_of.len(), rows * cols);
        let ncells = cell_of
            .iter()
            .filter(|&&c| c != usize::MAX)
            .map(|&c| c + 1)
            .max()
            .unwrap_or(0);
        let mut sizes = vec![0usize; ncells];
        for &c in &cell_of {
            if c != usize::MAX {
                sizes[c] += 1;
            }
        }
        assert!(sizes.iter().all(|&s| s > 0), "empty cell in partition");
        CellPartition { rows, cols, cell_of, sizes }
    }

    /// Circulant structure: cell `d` = wrap-around diagonal
    /// `{(i, j) : (j − i) mod n = d}` (square or rectangular wrap on cols).
    pub fn circulant(rows: usize, cols: usize) -> Self {
        let n = cols;
        let map = (0..rows * cols)
            .map(|e| {
                let (i, j) = (e / cols, e % cols);
                (j + n - (i % n)) % n
            })
            .collect();
        Self::from_map(rows, cols, map)
    }

    /// Toeplitz structure: cell = diagonal `j − i + (rows − 1)`.
    pub fn toeplitz(rows: usize, cols: usize) -> Self {
        let map = (0..rows * cols)
            .map(|e| {
                let (i, j) = (e / cols, e % cols);
                j + rows - 1 - i
            })
            .collect();
        Self::from_map(rows, cols, map)
    }

    /// Hankel structure: cell = anti-diagonal `i + j`.
    pub fn hankel(rows: usize, cols: usize) -> Self {
        let map = (0..rows * cols)
            .map(|e| {
                let (i, j) = (e / cols, e % cols);
                i + j
            })
            .collect();
        Self::from_map(rows, cols, map)
    }

    /// Constant-by-row cells.
    pub fn rows(rows: usize, cols: usize) -> Self {
        let map = (0..rows * cols).map(|e| e / cols).collect();
        Self::from_map(rows, cols, map)
    }

    /// Constant-by-column cells.
    pub fn cols(rows: usize, cols: usize) -> Self {
        let map = (0..rows * cols).map(|e| e % cols).collect();
        Self::from_map(rows, cols, map)
    }

    /// Number of cells.
    pub fn ncells(&self) -> usize {
        self.sizes.len()
    }

    /// Max non-zeros of a feasible matrix with `s` active cells: the `s`
    /// largest cells.
    pub fn max_nnz(&self, s: usize) -> usize {
        let mut sz = self.sizes.clone();
        sz.sort_unstable_by(|a, b| b.cmp(a));
        sz.iter().take(s).sum()
    }

    /// Check `m` is constant per cell, zero off-cells, ≤ `s` active cells.
    pub fn is_feasible(&self, m: &Mat, s: usize) -> bool {
        let mut vals: Vec<Option<f64>> = vec![None; self.ncells()];
        for (e, &c) in self.cell_of.iter().enumerate() {
            let v = m.data()[e];
            if c == usize::MAX {
                if v != 0.0 {
                    return false;
                }
                continue;
            }
            match vals[c] {
                None => vals[c] = Some(v),
                Some(prev) => {
                    if (prev - v).abs() > 1e-12 * (1.0 + prev.abs()) {
                        return false;
                    }
                }
            }
        }
        let active = vals
            .iter()
            .filter(|v| matches!(v, Some(x) if *x != 0.0))
            .count();
        active <= s
    }
}

/// Prop. A.2 projection: best sparse piecewise-constant unit-norm
/// approximation of `u` with at most `s` active cells.
pub fn proj_piecewise_const(u: &Mat, part: &CellPartition, s: usize) -> Mat {
    assert_eq!((u.rows(), u.cols()), (part.rows, part.cols));
    let ncells = part.ncells();
    // Cell sums ũ_i.
    let mut cell_sum = vec![0.0; ncells];
    for (e, &c) in part.cell_of.iter().enumerate() {
        if c != usize::MAX {
            cell_sum[c] += u.data()[e];
        }
    }
    // Scores |ũ_i| / √|C_i| (the ṽ of the proof).
    let scores: Vec<f64> = (0..ncells)
        .map(|c| cell_sum[c] / (part.sizes[c] as f64).sqrt())
        .collect();
    let keep = top_k_indices(&scores, s.min(ncells));
    // Optimal unnormalized values a_i = ũ_i / |C_i|; then normalize so
    // Σ |C_i| a_i² = 1.
    let mut norm2 = 0.0;
    let mut a = vec![0.0; ncells];
    for &c in &keep {
        let v = cell_sum[c] / part.sizes[c] as f64;
        a[c] = v;
        norm2 += part.sizes[c] as f64 * v * v;
    }
    let scale = if norm2 > 0.0 { 1.0 / norm2.sqrt() } else { 0.0 };
    let mut out = Mat::zeros(u.rows(), u.cols());
    for (e, &c) in part.cell_of.iter().enumerate() {
        if c != usize::MAX && a[c] != 0.0 {
            out.data_mut()[e] = a[c] * scale;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn circulant_partition_shape() {
        let p = CellPartition::circulant(4, 4);
        assert_eq!(p.ncells(), 4);
        assert!(p.sizes.iter().all(|&s| s == 4));
    }

    #[test]
    fn toeplitz_partition_shape() {
        let p = CellPartition::toeplitz(3, 5);
        assert_eq!(p.ncells(), 7); // rows + cols - 1 diagonals
    }

    #[test]
    fn projection_is_feasible_and_unit_norm() {
        let mut rng = Rng::new(71);
        let u = Mat::randn(6, 6, &mut rng);
        for (part, s) in [
            (CellPartition::circulant(6, 6), 3usize),
            (CellPartition::toeplitz(6, 6), 4),
            (CellPartition::hankel(6, 6), 4),
            (CellPartition::rows(6, 6), 2),
            (CellPartition::cols(6, 6), 2),
        ] {
            let p = proj_piecewise_const(&u, &part, s);
            assert!(part.is_feasible(&p, s));
            assert!((p.fro() - 1.0).abs() < 1e-12);
            // Idempotent.
            let p2 = proj_piecewise_const(&p, &part, s);
            assert!(p2.rel_fro_err(&p) < 1e-12);
        }
    }

    #[test]
    fn projection_of_feasible_point_is_itself() {
        // Build a circulant matrix with 2 active diagonals, unit norm.
        let part = CellPartition::circulant(5, 5);
        let mut m = Mat::zeros(5, 5);
        for (e, &c) in part.cell_of.iter().enumerate() {
            if c == 0 {
                m.data_mut()[e] = 2.0;
            } else if c == 2 {
                m.data_mut()[e] = -1.0;
            }
        }
        m.scale(1.0 / m.fro());
        let p = proj_piecewise_const(&m, &part, 2);
        assert!(p.rel_fro_err(&m) < 1e-12);
    }

    /// Optimality vs random feasible candidates (this is the test that
    /// distinguishes the correct `ũ_i / |C_i|` values from the equal-size
    /// shortcut — use *unequal* cell sizes).
    #[test]
    fn projection_optimal_vs_random_feasible_unequal_cells() {
        let mut rng = Rng::new(72);
        // Toeplitz 4x6 has diagonals of sizes 1..4 — unequal.
        let part = CellPartition::toeplitz(4, 6);
        for _ in 0..10 {
            let u = Mat::randn(4, 6, &mut rng);
            let s = 3;
            let p = proj_piecewise_const(&u, &part, s);
            let d_star = p.sub(&u).fro();
            for _ in 0..100 {
                // Random feasible candidate: s random cells, random values.
                let cells = rng.sample_indices(part.ncells(), s);
                let mut cand = Mat::zeros(4, 6);
                let vals: Vec<f64> = (0..s).map(|_| rng.gauss()).collect();
                for (e, &c) in part.cell_of.iter().enumerate() {
                    if let Some(pos) = cells.iter().position(|&cc| cc == c) {
                        cand.data_mut()[e] = vals[pos];
                    }
                }
                let f = cand.fro();
                if f == 0.0 {
                    continue;
                }
                cand.scale(1.0 / f);
                assert!(part.is_feasible(&cand, s));
                let d = cand.sub(&u).fro();
                assert!(d_star <= d + 1e-10, "suboptimal: {d_star} > {d}");
            }
        }
    }

    #[test]
    fn constant_row_projection_averages() {
        // Single row cell active: value = row mean (scaled to unit norm).
        let u = Mat::from_vec(2, 3, vec![1.0, 2.0, 3.0, 0.0, 0.0, 0.0]);
        let part = CellPartition::rows(2, 3);
        let p = proj_piecewise_const(&u, &part, 1);
        // Row 0 mean = 2.0 > row 1 mean — row 0 kept, constant.
        assert!(p.at(0, 0) == p.at(0, 1) && p.at(0, 1) == p.at(0, 2));
        assert_eq!(p.at(1, 0), 0.0);
        assert!((p.fro() - 1.0).abs() < 1e-12);
    }
}
