//! Projection operators onto the paper's constraint sets (Appendix A).
//!
//! palm4MSA needs, for every factor, the Euclidean projection onto
//! `E = N ∩ S` where `N` is the unit-Frobenius-norm sphere and `S` a
//! sparsity (or structure) set. Proposition A.1 covers partition-wise
//! sparsity (global / per-row / per-column / fixed support / triangular /
//! diagonal); Proposition A.2 covers sparse piecewise-constant matrices
//! (circulant / Toeplitz / Hankel with prescribed diagonal sparsity,
//! constant-by-row/column, and general cell partitions).
//!
//! All projections map the zero matrix to itself (the normalization is
//! skipped when nothing survives the support selection), which keeps PALM
//! iterations well-defined from the paper's all-zeros `S₁⁰` init.

#![forbid(unsafe_code)]

use crate::linalg::Mat;

mod piecewise;
mod sparsity;

pub use piecewise::{proj_piecewise_const, CellPartition};
pub use sparsity::{
    proj_sp, proj_sp_partition, proj_spcol, proj_splincol, proj_sprow, proj_support,
    top_k_indices,
};

/// Constraint set `E_j` attached to one factor of a FAμST.
///
/// Every variant describes a set of the form `{S : structural constraint,
/// ‖S‖_F = 1}` except [`Constraint::Frozen`] (projection = keep current
/// value; used for the coefficient matrix Γ in Fig. 11's dictionary
/// variant) and [`Constraint::Unconstrained`].
#[derive(Clone, Debug, PartialEq)]
pub enum Constraint {
    /// `‖S‖₀ ≤ s` globally ("sp" in the FAμST toolbox).
    SpGlobal(usize),
    /// Each column has at most `k` non-zeros ("spcol").
    SpCol(usize),
    /// Each row has at most `k` non-zeros ("splin").
    SpRow(usize),
    /// Union of the top-`k`-per-row and top-`k`-per-column supports
    /// ("splincol" in the FAμST toolbox). Not a projection onto an
    /// intersection set — it keeps every entry that is among the `k`
    /// largest of its row *or* of its column — but it is the operator the
    /// reference implementation uses for butterfly-structured targets
    /// (Hadamard §IV-C), where plain global top-`k` collapses under the
    /// massive magnitude ties.
    SpRowCol(usize),
    /// Fixed support mask (row-major booleans, same shape as the factor).
    Support(Vec<bool>),
    /// Upper-triangular (incl. diagonal) with `‖S‖₀ ≤ s`.
    SpTriUpper(usize),
    /// Lower-triangular (incl. diagonal) with `‖S‖₀ ≤ s`.
    SpTriLower(usize),
    /// Diagonal matrix (normalized).
    Diagonal,
    /// Circulant: constant on wrap-around diagonals, at most `s` non-zero
    /// diagonals.
    Circulant(usize),
    /// Toeplitz: constant on diagonals, at most `s` non-zero diagonals.
    Toeplitz(usize),
    /// Hankel: constant on anti-diagonals, at most `s` non-zero.
    Hankel(usize),
    /// Constant within each row, at most `s` non-zero rows.
    ConstRow(usize),
    /// Constant within each column, at most `s` non-zero columns.
    ConstCol(usize),
    /// Keep the current value (factor not optimized; Fig. 11's Γ).
    Frozen,
    /// Identity projection (no constraint; not normalized).
    Unconstrained,
}

impl Constraint {
    /// Euclidean projection of `u` onto this constraint set.
    pub fn project(&self, u: &Mat) -> Mat {
        match self {
            Constraint::SpGlobal(s) => proj_sp(u, *s),
            Constraint::SpCol(k) => proj_spcol(u, *k),
            Constraint::SpRow(k) => proj_sprow(u, *k),
            Constraint::SpRowCol(k) => proj_splincol(u, *k),
            Constraint::Support(mask) => proj_support(u, mask),
            Constraint::SpTriUpper(s) => {
                let masked = mask_tri(u, true);
                proj_sp(&masked, *s)
            }
            Constraint::SpTriLower(s) => {
                let masked = mask_tri(u, false);
                proj_sp(&masked, *s)
            }
            Constraint::Diagonal => {
                let mut mask = vec![false; u.rows() * u.cols()];
                for i in 0..u.rows().min(u.cols()) {
                    mask[i * u.cols() + i] = true;
                }
                proj_support(u, &mask)
            }
            Constraint::Circulant(s) => {
                proj_piecewise_const(u, &CellPartition::circulant(u.rows(), u.cols()), *s)
            }
            Constraint::Toeplitz(s) => {
                proj_piecewise_const(u, &CellPartition::toeplitz(u.rows(), u.cols()), *s)
            }
            Constraint::Hankel(s) => {
                proj_piecewise_const(u, &CellPartition::hankel(u.rows(), u.cols()), *s)
            }
            Constraint::ConstRow(s) => {
                proj_piecewise_const(u, &CellPartition::rows(u.rows(), u.cols()), *s)
            }
            Constraint::ConstCol(s) => {
                proj_piecewise_const(u, &CellPartition::cols(u.rows(), u.cols()), *s)
            }
            Constraint::Frozen => u.clone(),
            Constraint::Unconstrained => u.clone(),
        }
    }

    /// Is `m` feasible for this set (up to `tol` on the norm)?
    pub fn is_feasible(&self, m: &Mat, tol: f64) -> bool {
        let normed = |m: &Mat| (m.fro() - 1.0).abs() <= tol || m.fro() == 0.0;
        match self {
            Constraint::SpGlobal(s) => m.nnz() <= *s && normed(m),
            Constraint::SpCol(k) => {
                (0..m.cols()).all(|j| m.col(j).iter().filter(|x| **x != 0.0).count() <= *k)
                    && normed(m)
            }
            Constraint::SpRow(k) => {
                (0..m.rows()).all(|i| m.row(i).iter().filter(|x| **x != 0.0).count() <= *k)
                    && normed(m)
            }
            Constraint::SpRowCol(k) => {
                // Union support: total nnz cannot exceed k(rows+cols).
                m.nnz() <= k * (m.rows() + m.cols()) && normed(m)
            }
            Constraint::Support(mask) => {
                m.data()
                    .iter()
                    .zip(mask)
                    .all(|(v, &ok)| ok || *v == 0.0)
                    && normed(m)
            }
            Constraint::SpTriUpper(s) => {
                m.nnz() <= *s
                    && normed(m)
                    && (0..m.rows()).all(|i| (0..i.min(m.cols())).all(|j| m.at(i, j) == 0.0))
            }
            Constraint::SpTriLower(s) => {
                m.nnz() <= *s
                    && normed(m)
                    && (0..m.rows())
                        .all(|i| ((i + 1)..m.cols()).all(|j| m.at(i, j) == 0.0))
            }
            Constraint::Diagonal => {
                (0..m.rows()).all(|i| (0..m.cols()).all(|j| i == j || m.at(i, j) == 0.0))
                    && normed(m)
            }
            Constraint::Circulant(s) => {
                CellPartition::circulant(m.rows(), m.cols()).is_feasible(m, *s) && normed(m)
            }
            Constraint::Toeplitz(s) => {
                CellPartition::toeplitz(m.rows(), m.cols()).is_feasible(m, *s) && normed(m)
            }
            Constraint::Hankel(s) => {
                CellPartition::hankel(m.rows(), m.cols()).is_feasible(m, *s) && normed(m)
            }
            Constraint::ConstRow(s) => {
                CellPartition::rows(m.rows(), m.cols()).is_feasible(m, *s) && normed(m)
            }
            Constraint::ConstCol(s) => {
                CellPartition::cols(m.rows(), m.cols()).is_feasible(m, *s) && normed(m)
            }
            Constraint::Frozen | Constraint::Unconstrained => true,
        }
    }

    /// Upper bound on the number of non-zeros a feasible matrix may have —
    /// the `s_j` entering RC/RCG accounting (§II-B).
    pub fn max_nnz(&self, rows: usize, cols: usize) -> usize {
        match self {
            Constraint::SpGlobal(s) => (*s).min(rows * cols),
            Constraint::SpCol(k) => k.min(&rows) * cols,
            Constraint::SpRow(k) => k.min(&cols) * rows,
            Constraint::SpRowCol(k) => (k * (rows + cols)).min(rows * cols),
            Constraint::Support(mask) => mask.iter().filter(|&&b| b).count(),
            Constraint::SpTriUpper(s) | Constraint::SpTriLower(s) => (*s).min(rows * cols),
            Constraint::Diagonal => rows.min(cols),
            Constraint::Circulant(s) => CellPartition::circulant(rows, cols).max_nnz(*s),
            Constraint::Toeplitz(s) => CellPartition::toeplitz(rows, cols).max_nnz(*s),
            Constraint::Hankel(s) => CellPartition::hankel(rows, cols).max_nnz(*s),
            Constraint::ConstRow(s) => CellPartition::rows(rows, cols).max_nnz(*s),
            Constraint::ConstCol(s) => CellPartition::cols(rows, cols).max_nnz(*s),
            Constraint::Frozen | Constraint::Unconstrained => rows * cols,
        }
    }
}

/// Zero out the strict lower (if `upper`) or strict upper triangle.
fn mask_tri(u: &Mat, upper: bool) -> Mat {
    Mat::from_fn(u.rows(), u.cols(), |i, j| {
        let keep = if upper { j >= i } else { j <= i };
        if keep {
            u.at(i, j)
        } else {
            0.0
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn project_is_idempotent_for_all_variants() {
        let mut rng = Rng::new(51);
        let u = Mat::randn(6, 6, &mut rng);
        let mut mask = vec![false; 36];
        for i in [0usize, 5, 11, 17, 23, 29, 35] {
            mask[i] = true;
        }
        let cs = vec![
            Constraint::SpGlobal(7),
            Constraint::SpCol(2),
            Constraint::SpRow(2),
            Constraint::Support(mask),
            Constraint::SpTriUpper(5),
            Constraint::SpTriLower(5),
            Constraint::Diagonal,
            Constraint::Circulant(3),
            Constraint::Toeplitz(4),
            Constraint::Hankel(4),
            Constraint::ConstRow(3),
            Constraint::ConstCol(3),
        ];
        for c in cs {
            let p1 = c.project(&u);
            let p2 = c.project(&p1);
            assert!(
                p2.rel_fro_err(&p1) < 1e-12,
                "projection not idempotent for {c:?}"
            );
            assert!(c.is_feasible(&p1, 1e-12), "projection infeasible for {c:?}");
        }
    }

    #[test]
    fn projection_of_zero_is_zero() {
        let z = Mat::zeros(4, 4);
        for c in [
            Constraint::SpGlobal(3),
            Constraint::SpCol(1),
            Constraint::Diagonal,
            Constraint::Circulant(2),
        ] {
            let p = c.project(&z);
            assert_eq!(p.nnz(), 0, "{c:?}");
        }
    }

    #[test]
    fn max_nnz_bounds_projection() {
        let mut rng = Rng::new(52);
        let u = Mat::randn(5, 7, &mut rng);
        for c in [
            Constraint::SpGlobal(9),
            Constraint::SpCol(2),
            Constraint::SpRow(3),
            Constraint::Diagonal,
            Constraint::Toeplitz(4),
            Constraint::ConstCol(2),
        ] {
            let p = c.project(&u);
            assert!(
                p.nnz() <= c.max_nnz(5, 7),
                "{c:?}: nnz={} > bound={}",
                p.nnz(),
                c.max_nnz(5, 7)
            );
        }
    }

    #[test]
    fn frozen_keeps_value() {
        let mut rng = Rng::new(53);
        let u = Mat::randn(3, 4, &mut rng);
        let p = Constraint::Frozen.project(&u);
        assert!(p.rel_fro_err(&u) < 1e-15);
    }

    #[test]
    fn triangular_projection_structure() {
        let mut rng = Rng::new(54);
        let u = Mat::randn(5, 5, &mut rng);
        let p = Constraint::SpTriUpper(25).project(&u);
        for i in 0..5 {
            for j in 0..i {
                assert_eq!(p.at(i, j), 0.0);
            }
        }
        let pl = Constraint::SpTriLower(25).project(&u);
        for i in 0..5 {
            for j in (i + 1)..5 {
                assert_eq!(pl.at(i, j), 0.0);
            }
        }
    }

    #[test]
    fn diagonal_projection_keeps_diagonal_direction() {
        let mut rng = Rng::new(55);
        let u = Mat::randn(4, 4, &mut rng);
        let p = Constraint::Diagonal.project(&u);
        let diag_norm: f64 = (0..4).map(|i| u.at(i, i) * u.at(i, i)).sum::<f64>().sqrt();
        for i in 0..4 {
            assert!((p.at(i, i) - u.at(i, i) / diag_norm).abs() < 1e-12);
        }
    }
}
