//! Proposition A.1 — projections onto partition-wise sparse, unit-norm sets.
//!
//! `P_E(U) = U_I / ‖U_I‖_F` where `I` keeps, within each partition block
//! `H_i`, the `s_i` entries of largest magnitude. Global sparsity, per-row,
//! per-column, and fixed-support projections are all instances.

use crate::linalg::Mat;

/// Indices of the `k` largest-|value| entries of `v` — O(n) via
/// `select_nth_unstable` (no full sort; this sits in the PALM hot loop).
pub fn top_k_indices(v: &[f64], k: usize) -> Vec<usize> {
    let n = v.len();
    if k == 0 {
        return vec![];
    }
    if k >= n {
        return (0..n).collect();
    }
    let mut idx: Vec<usize> = (0..n).collect();
    // Ties broken by index (ascending) → deterministic, Matlab-stable-sort
    // compatible, which matters on operators with massive magnitude ties
    // (every |entry| of a Hadamard matrix is equal).
    idx.select_nth_unstable_by(k - 1, |&a, &b| {
        v[b].abs()
            .partial_cmp(&v[a].abs())
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    idx.truncate(k);
    idx
}

/// Normalize to unit Frobenius norm in place (no-op on the zero matrix).
fn normalize(m: &mut Mat) {
    let f = m.fro();
    if f > 0.0 {
        m.scale(1.0 / f);
    }
}

/// Global sparsity projection: keep the `s` largest-magnitude entries and
/// normalize (Prop. A.1 with the trivial partition).
pub fn proj_sp(u: &Mat, s: usize) -> Mat {
    let mut out = Mat::zeros(u.rows(), u.cols());
    for i in top_k_indices(u.data(), s) {
        out.data_mut()[i] = u.data()[i];
    }
    normalize(&mut out);
    out
}

/// Per-column sparsity: keep the `k` largest entries of **each** column,
/// then normalize the whole matrix (Prop. A.1, partition = columns).
pub fn proj_spcol(u: &Mat, k: usize) -> Mat {
    let mut out = Mat::zeros(u.rows(), u.cols());
    for j in 0..u.cols() {
        let col = u.col(j);
        for i in top_k_indices(&col, k) {
            out.set(i, j, col[i]);
        }
    }
    normalize(&mut out);
    out
}

/// Per-row sparsity: keep the `k` largest entries of each row, normalize.
pub fn proj_sprow(u: &Mat, k: usize) -> Mat {
    let mut out = Mat::zeros(u.rows(), u.cols());
    for i in 0..u.rows() {
        let row = u.row(i);
        for j in top_k_indices(row, k) {
            out.set(i, j, row[j]);
        }
    }
    normalize(&mut out);
    out
}

/// "splincol" (FAμST toolbox): keep the union of the top-`k`-per-row and
/// top-`k`-per-column supports, normalize. Breaks the magnitude-tie
/// degeneracy of global top-`k` on butterfly-structured operators by
/// forcing every row and column to stay populated.
pub fn proj_splincol(u: &Mat, k: usize) -> Mat {
    let mut keep = vec![false; u.rows() * u.cols()];
    for i in 0..u.rows() {
        let row = u.row(i);
        for j in top_k_indices(row, k) {
            keep[i * u.cols() + j] = true;
        }
    }
    for j in 0..u.cols() {
        let col = u.col(j);
        for i in top_k_indices(&col, k) {
            keep[i * u.cols() + j] = true;
        }
    }
    let mut out = Mat::zeros(u.rows(), u.cols());
    for (e, &kf) in keep.iter().enumerate() {
        if kf {
            out.data_mut()[e] = u.data()[e];
        }
    }
    normalize(&mut out);
    out
}

/// Fixed-support projection: zero outside `mask`, normalize.
pub fn proj_support(u: &Mat, mask: &[bool]) -> Mat {
    assert_eq!(mask.len(), u.rows() * u.cols(), "support mask shape mismatch");
    let mut out = u.clone();
    for (v, &keep) in out.data_mut().iter_mut().zip(mask) {
        if !keep {
            *v = 0.0;
        }
    }
    normalize(&mut out);
    out
}

/// General Prop. A.1: partition the index set into blocks (`groups[e]` is
/// the block id of flat entry `e`), keep the `s_i` largest per block,
/// normalize globally.
pub fn proj_sp_partition(u: &Mat, groups: &[usize], s_per_group: &[usize]) -> Mat {
    assert_eq!(groups.len(), u.rows() * u.cols());
    let ngroups = s_per_group.len();
    // Gather entries per group.
    let mut members: Vec<Vec<usize>> = vec![vec![]; ngroups];
    for (e, &g) in groups.iter().enumerate() {
        assert!(g < ngroups, "group id out of range");
        members[g].push(e);
    }
    let mut out = Mat::zeros(u.rows(), u.cols());
    for (g, ms) in members.iter().enumerate() {
        let vals: Vec<f64> = ms.iter().map(|&e| u.data()[e]).collect();
        for local in top_k_indices(&vals, s_per_group[g]) {
            out.data_mut()[ms[local]] = vals[local];
        }
    }
    normalize(&mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn top_k_selects_largest() {
        let v = [1.0, -5.0, 3.0, 0.5, -2.0];
        let mut idx = top_k_indices(&v, 2);
        idx.sort_unstable();
        assert_eq!(idx, vec![1, 2]);
        assert_eq!(top_k_indices(&v, 0).len(), 0);
        assert_eq!(top_k_indices(&v, 9).len(), 5);
    }

    #[test]
    fn proj_sp_keeps_top_entries_and_normalizes() {
        let u = Mat::from_vec(2, 3, vec![3.0, -1.0, 0.2, -4.0, 0.1, 0.05]);
        let p = proj_sp(&u, 2);
        assert_eq!(p.nnz(), 2);
        assert!((p.fro() - 1.0).abs() < 1e-12);
        // The two largest are -4 and 3.
        assert!(p.at(1, 0) != 0.0 && p.at(0, 0) != 0.0);
        // Direction preserved: ratio matches.
        assert!((p.at(1, 0) / p.at(0, 0) - (-4.0 / 3.0)).abs() < 1e-12);
    }

    #[test]
    fn proj_spcol_col_budget() {
        let mut rng = Rng::new(61);
        let u = Mat::randn(10, 4, &mut rng);
        let p = proj_spcol(&u, 3);
        for j in 0..4 {
            let nz = p.col(j).iter().filter(|x| **x != 0.0).count();
            assert_eq!(nz, 3);
        }
        assert!((p.fro() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn proj_sprow_row_budget() {
        let mut rng = Rng::new(62);
        let u = Mat::randn(5, 9, &mut rng);
        let p = proj_sprow(&u, 2);
        for i in 0..5 {
            let nz = p.row(i).iter().filter(|x| **x != 0.0).count();
            assert_eq!(nz, 2);
        }
    }

    #[test]
    fn proj_support_zeroes_complement() {
        let mut rng = Rng::new(63);
        let u = Mat::randn(3, 3, &mut rng);
        let mask: Vec<bool> = (0..9).map(|i| i % 2 == 0).collect();
        let p = proj_support(&u, &mask);
        for (i, &keep) in mask.iter().enumerate() {
            if !keep {
                assert_eq!(p.data()[i], 0.0);
            }
        }
        assert!((p.fro() - 1.0).abs() < 1e-12);
    }

    /// Optimality check (Prop. A.1): the projection is at least as close to
    /// U as any random feasible point.
    #[test]
    fn proj_sp_is_optimal_vs_random_feasible() {
        let mut rng = Rng::new(64);
        for trial in 0..20 {
            let u = Mat::randn(4, 5, &mut rng);
            let s = 1 + (trial % 6);
            let p = proj_sp(&u, s);
            let d_star = p.sub(&u).fro();
            for _ in 0..50 {
                // Random s-sparse unit-norm matrix.
                let mut cand = Mat::zeros(4, 5);
                for i in rng.sample_indices(20, s) {
                    cand.data_mut()[i] = rng.gauss();
                }
                let f = cand.fro();
                if f == 0.0 {
                    continue;
                }
                cand.scale(1.0 / f);
                let d = cand.sub(&u).fro();
                assert!(
                    d_star <= d + 1e-10,
                    "projection suboptimal: {d_star} > {d}"
                );
            }
        }
    }

    #[test]
    fn partition_projection_generalizes_global() {
        let mut rng = Rng::new(65);
        let u = Mat::randn(6, 6, &mut rng);
        // One group covering everything == proj_sp.
        let groups = vec![0usize; 36];
        let p1 = proj_sp_partition(&u, &groups, &[7]);
        let p2 = proj_sp(&u, 7);
        assert!(p1.rel_fro_err(&p2) < 1e-12);
        // Column groups == proj_spcol.
        let col_groups: Vec<usize> = (0..36).map(|e| e % 6).collect();
        let p3 = proj_sp_partition(&u, &col_groups, &[2; 6]);
        let p4 = proj_spcol(&u, 2);
        assert!(p3.rel_fro_err(&p4) < 1e-12);
    }
}
