//! Mini property-testing framework (proptest is not in the offline vendor
//! set). Seeded generators + a runner that reports the failing case's seed
//! so any counterexample is reproducible.

#![forbid(unsafe_code)]

use crate::faust::Faust;
use crate::linalg::Mat;
use crate::rng::Rng;

/// Configuration for a property run.
#[derive(Clone, Debug)]
pub struct PropConfig {
    pub cases: usize,
    pub base_seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig { cases: 64, base_seed: 0xC0FFEE }
    }
}

/// Run `prop` on `cfg.cases` independently-seeded RNGs; panics with the
/// offending case seed on the first failure (returned `Err(reason)`).
pub fn check(name: &str, cfg: &PropConfig, mut prop: impl FnMut(&mut Rng) -> Result<(), String>) {
    for case in 0..cfg.cases {
        let seed = cfg.base_seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        if let Err(reason) = prop(&mut rng) {
            panic!("property '{name}' failed on case {case} (seed {seed:#x}): {reason}");
        }
    }
}

/// Assert helper producing `Result` for use inside properties.
pub fn ensure(cond: bool, msg: impl Into<String>) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Bit-exact fingerprint of a [`Faust`]: λ's bits plus every factor
/// entry's bits (densified, rightmost first). Two operators fingerprint
/// equal iff they are numerically identical down to the last ulp — the
/// thread-determinism proptests and the `factorize_scaling` bench share
/// this definition.
pub fn faust_fingerprint(f: &Faust) -> (u64, Vec<Vec<u64>>) {
    let facs = f
        .factors()
        .iter()
        .map(|c| c.to_dense().data().iter().map(|v| v.to_bits()).collect())
        .collect();
    (f.lambda().to_bits(), facs)
}

/// Generators for common test inputs.
pub mod gen {
    use super::*;

    /// Matrix with dims drawn from `[1, max_dim]`.
    pub fn mat(rng: &mut Rng, max_dim: usize) -> Mat {
        let r = 1 + rng.below(max_dim);
        let c = 1 + rng.below(max_dim);
        Mat::randn(r, c, rng)
    }

    /// Matrix of exactly the given shape.
    pub fn mat_shaped(rng: &mut Rng, rows: usize, cols: usize) -> Mat {
        Mat::randn(rows, cols, rng)
    }

    /// Sparse matrix with `nnz` random non-zeros.
    pub fn sparse_mat(rng: &mut Rng, rows: usize, cols: usize, nnz: usize) -> Mat {
        let mut m = Mat::zeros(rows, cols);
        for i in rng.sample_indices(rows * cols, nnz.min(rows * cols)) {
            m.data_mut()[i] = rng.gauss();
        }
        m
    }

    /// k-sparse vector of length n with entries bounded away from zero.
    pub fn sparse_vec(rng: &mut Rng, n: usize, k: usize) -> Vec<f64> {
        let mut v = vec![0.0; n];
        for i in rng.sample_indices(n, k) {
            v[i] = rng.gauss() + 1.5 * rng.gauss().signum();
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        check("trivial", &PropConfig::default(), |rng| {
            let u = rng.uniform();
            ensure((0.0..1.0).contains(&u), format!("u out of range: {u}"))
        });
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn check_reports_failure_with_seed() {
        check(
            "fails",
            &PropConfig { cases: 5, base_seed: 1 },
            |_| Err("always".into()),
        );
    }

    #[test]
    fn generators_produce_valid_shapes() {
        let mut rng = Rng::new(3);
        for _ in 0..20 {
            let m = gen::mat(&mut rng, 10);
            assert!(m.rows() >= 1 && m.rows() <= 10);
            let s = gen::sparse_mat(&mut rng, 6, 6, 10);
            assert!(s.nnz() <= 10);
            let v = gen::sparse_vec(&mut rng, 12, 3);
            assert_eq!(v.iter().filter(|x| **x != 0.0).count(), 3);
        }
    }
}
