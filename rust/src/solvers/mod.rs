//! Sparse-recovery solvers over abstract linear operators.
//!
//! The paper's §V use-case: iterative solvers whose cost is dominated by
//! products with the measurement matrix `M` and `Mᵀ` — replacing `M` by a
//! FAμST accelerates them by ≈ RCG. Everything here is written against the
//! [`LinOp`] trait so dense matrices, FAμSTs, and PJRT-compiled operators
//! are interchangeable.

#![forbid(unsafe_code)]

mod fista;
mod iht;
mod omp;
mod omp_gram;

pub use fista::{fista, soft_threshold, FistaResult};
pub use iht::{iht, IhtResult};
pub use omp::{omp, omp_batch, OmpResult};
pub use omp_gram::omp_batch_gram;

use crate::faust::Faust;
use crate::linalg::Mat;

/// Abstract linear operator `R^n -> R^m` with transpose access.
pub trait LinOp {
    fn rows(&self) -> usize;
    fn cols(&self) -> usize;
    /// `y = A x`.
    fn apply(&self, x: &[f64]) -> Vec<f64>;
    /// `y = Aᵀ x`.
    fn apply_t(&self, x: &[f64]) -> Vec<f64>;
    /// Column `j` (default: apply to a canonical basis vector).
    fn column(&self, j: usize) -> Vec<f64> {
        let mut e = vec![0.0; self.cols()];
        e[j] = 1.0;
        self.apply(&e)
    }
    /// Flops for one apply (2mn for dense; 2·s_tot for a FAμST).
    fn flops_per_apply(&self) -> usize;
    /// Rough spectral-norm-squared upper bound for step sizes.
    fn gram_norm_estimate(&self, seed: u64) -> f64 {
        // Power iteration on AᵀA through the trait.
        let mut rng = crate::rng::Rng::new(seed);
        let mut x = rng.gauss_vec(self.cols());
        let mut est = 0.0;
        for _ in 0..30 {
            let y = self.apply(&x);
            let z = self.apply_t(&y);
            let nz: f64 = z.iter().map(|v| v * v).sum::<f64>().sqrt();
            if nz < 1e-300 {
                return 0.0;
            }
            for (xi, zi) in x.iter_mut().zip(&z) {
                *xi = zi / nz;
            }
            est = nz;
        }
        est
    }
}

impl LinOp for Mat {
    fn rows(&self) -> usize {
        Mat::rows(self)
    }
    fn cols(&self) -> usize {
        Mat::cols(self)
    }
    fn apply(&self, x: &[f64]) -> Vec<f64> {
        self.matvec(x)
    }
    fn apply_t(&self, x: &[f64]) -> Vec<f64> {
        self.matvec_t(x)
    }
    fn column(&self, j: usize) -> Vec<f64> {
        Mat::col(self, j)
    }
    fn flops_per_apply(&self) -> usize {
        2 * Mat::rows(self) * Mat::cols(self)
    }
}

impl LinOp for Faust {
    fn rows(&self) -> usize {
        Faust::rows(self)
    }
    fn cols(&self) -> usize {
        Faust::cols(self)
    }
    fn apply(&self, x: &[f64]) -> Vec<f64> {
        Faust::apply(self, x)
    }
    fn apply_t(&self, x: &[f64]) -> Vec<f64> {
        Faust::apply_t(self, x)
    }
    fn column(&self, j: usize) -> Vec<f64> {
        Faust::column(self, j)
    }
    fn flops_per_apply(&self) -> usize {
        self.flops_per_matvec()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn linop_dense_and_faust_agree() {
        let mut rng = Rng::new(111);
        let a = Mat::randn(6, 10, &mut rng);
        let f = Faust::from_dense(&a);
        let x = rng.gauss_vec(10);
        let ya = LinOp::apply(&a, &x);
        let yf = LinOp::apply(&f, &x);
        for i in 0..6 {
            assert!((ya[i] - yf[i]).abs() < 1e-12);
        }
        let z = rng.gauss_vec(6);
        let ta = LinOp::apply_t(&a, &z);
        let tf = LinOp::apply_t(&f, &z);
        for j in 0..10 {
            assert!((ta[j] - tf[j]).abs() < 1e-12);
        }
        assert_eq!(LinOp::flops_per_apply(&a), 120);
    }

    #[test]
    fn gram_norm_estimate_close_to_spectral() {
        let mut rng = Rng::new(112);
        let a = Mat::randn(12, 8, &mut rng);
        let est = LinOp::gram_norm_estimate(&a, 1).sqrt();
        let truth = crate::linalg::spectral_norm(&a, &mut rng);
        assert!((est - truth).abs() < 0.05 * truth, "est={est} truth={truth}");
    }
}
