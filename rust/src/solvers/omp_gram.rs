//! Batch OMP with precomputed Gram matrix (Rubinstein–Zibulevsky–Elad,
//! "Efficient Implementation of the K-SVD Algorithm using Batch
//! Orthogonal Matching Pursuit" — the paper's reference [47], used for
//! its DDL baseline).
//!
//! For `L` signals coded against the same dictionary, precomputing
//! `G = DᵀD` once turns each OMP iteration's correlation update into a
//! Gram-column accumulation (`O(n·k)` instead of a fresh `Dᵀr` product),
//! and the coefficients come from a progressively-updated Cholesky
//! factor. This is the coding engine K-SVD spends most of its time in.

use crate::linalg::Mat;

/// Progressive Cholesky state for one signal's support.
struct Chol {
    /// Lower-triangular factor, row-major, `k×k` packed into `k_max` rows.
    l: Vec<Vec<f64>>,
}

impl Chol {
    fn new() -> Self {
        Chol { l: vec![] }
    }

    /// Grow the factor with a new atom whose Gram column (restricted to
    /// the current support, in order) is `g_col` and self-inner-product
    /// `g_jj`. Returns false when the new atom is numerically dependent.
    fn push(&mut self, g_col: &[f64], g_jj: f64) -> bool {
        let k = self.l.len();
        debug_assert_eq!(g_col.len(), k);
        // Solve L w = g_col.
        let mut w = vec![0.0; k];
        for i in 0..k {
            let mut acc = g_col[i];
            for j in 0..i {
                acc -= self.l[i][j] * w[j];
            }
            w[i] = acc / self.l[i][i];
        }
        let d2 = g_jj - w.iter().map(|x| x * x).sum::<f64>();
        if d2 <= 1e-12 {
            return false;
        }
        let mut row = w;
        row.push(d2.sqrt());
        self.l.push(row);
        true
    }

    /// Solve `(L Lᵀ) x = b`.
    fn solve(&self, b: &[f64]) -> Vec<f64> {
        let k = self.l.len();
        debug_assert_eq!(b.len(), k);
        let mut y = vec![0.0; k];
        for i in 0..k {
            let mut acc = b[i];
            for j in 0..i {
                acc -= self.l[i][j] * y[j];
            }
            y[i] = acc / self.l[i][i];
        }
        let mut x = vec![0.0; k];
        for i in (0..k).rev() {
            let mut acc = y[i];
            for j in (i + 1)..k {
                acc -= self.l[j][i] * x[j];
            }
            x[i] = acc / self.l[i][i];
        }
        x
    }
}

/// Batch OMP: code every column of `y` with `k` atoms against dictionary
/// `d` (columns assumed ~unit norm, as K-SVD maintains), using one shared
/// precomputed Gram matrix. Returns `Γ` (`d.cols() × y.cols()`).
pub fn omp_batch_gram(d: &Mat, y: &Mat, k: usize) -> Mat {
    let n = d.cols();
    let k = k.min(n);
    let gram = d.matmul_tn(d); // G = DᵀD, n×n, once per batch
    let dty = d.matmul_tn(y); // initial correlations for every signal
    let mut gamma = Mat::zeros(n, y.cols());
    for c in 0..y.cols() {
        let alpha0: Vec<f64> = (0..n).map(|i| dty.at(i, c)).collect();
        let mut alpha = alpha0.clone(); // current correlations Dᵀr
        let mut support: Vec<usize> = Vec::with_capacity(k);
        let mut selected = vec![false; n];
        let mut chol = Chol::new();
        for _ in 0..k {
            // argmax |alpha| over unselected atoms.
            let mut best = None;
            let mut best_v = 1e-300;
            for j in 0..n {
                if !selected[j] && alpha[j].abs() > best_v {
                    best_v = alpha[j].abs();
                    best = Some(j);
                }
            }
            let Some(j) = best else { break };
            // Gram column of j restricted to the current support.
            let g_col: Vec<f64> = support.iter().map(|&s| gram.at(s, j)).collect();
            if !chol.push(&g_col, gram.at(j, j)) {
                break; // dependent atom — stop early
            }
            selected[j] = true;
            support.push(j);
            // coefficients x = (G_SS)^{-1} alpha0_S via the Cholesky.
            let b: Vec<f64> = support.iter().map(|&s| alpha0[s]).collect();
            let x = chol.solve(&b);
            // alpha = alpha0 − G_S x (correlation maintenance — no D·r!).
            alpha.copy_from_slice(&alpha0);
            for (si, &s) in support.iter().enumerate() {
                let xs = x[si];
                if xs == 0.0 {
                    continue;
                }
                for t in 0..n {
                    alpha[t] -= gram.at(t, s) * xs;
                }
            }
            if support.len() == k {
                for (si, &s) in support.iter().enumerate() {
                    gamma.set(s, c, x[si]);
                }
            }
        }
        // If we stopped early, write the last solved coefficients.
        if support.len() < k && !support.is_empty() {
            let b: Vec<f64> = support.iter().map(|&s| alpha0[s]).collect();
            let x = chol.solve(&b);
            for (si, &s) in support.iter().enumerate() {
                gamma.set(s, c, x[si]);
            }
        }
    }
    gamma
}

#[cfg(test)]
mod gram_tests {
    use super::super::omp_batch;
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn gram_batch_matches_plain_batch_omp() {
        let mut rng = Rng::new(171);
        let mut d = Mat::randn(12, 24, &mut rng);
        d.normalize_cols();
        let y = Mat::randn(12, 10, &mut rng);
        let g1 = omp_batch(&d, &y, 3);
        let g2 = omp_batch_gram(&d, &y, 3);
        // Same supports and near-identical coefficients.
        for c in 0..10 {
            for i in 0..24 {
                let a = g1.at(i, c);
                let b = g2.at(i, c);
                assert!(
                    (a == 0.0) == (b == 0.0),
                    "support mismatch at ({i},{c}): {a} vs {b}"
                );
                assert!((a - b).abs() < 1e-8, "coef mismatch: {a} vs {b}");
            }
        }
    }

    #[test]
    fn gram_batch_exact_on_orthogonal_dictionary() {
        let d = crate::transforms::hadamard(16);
        let mut rng = Rng::new(172);
        let mut gamma0 = Mat::zeros(16, 6);
        for c in 0..6 {
            for i in rng.sample_indices(16, 2) {
                gamma0.set(i, c, 1.0 + rng.uniform());
            }
        }
        let y = d.matmul(&gamma0);
        let g = omp_batch_gram(&d, &y, 2);
        assert!(g.rel_fro_err(&gamma0) < 1e-9);
    }

    #[test]
    fn gram_batch_handles_duplicate_atoms() {
        // Dictionary with a duplicated column: Cholesky must refuse the
        // dependent atom instead of dividing by ~0.
        let mut rng = Rng::new(173);
        let mut d = Mat::randn(8, 10, &mut rng);
        let c0 = d.col(0);
        d.set_col(5, &c0);
        d.normalize_cols();
        let y = Mat::randn(8, 4, &mut rng);
        let g = omp_batch_gram(&d, &y, 4);
        assert!(g.data().iter().all(|v| v.is_finite()));
    }
}
