//! FISTA (Beck & Teboulle) — the `l1`-regularized least-squares solver
//! standing in for the paper's `l1ls` baseline (§V-B).

use super::LinOp;

/// Soft-thresholding operator `sign(x)·max(|x|−t, 0)`.
pub fn soft_threshold(x: f64, t: f64) -> f64 {
    if x > t {
        x - t
    } else if x < -t {
        x + t
    } else {
        0.0
    }
}

/// Result of a FISTA run.
#[derive(Clone, Debug)]
pub struct FistaResult {
    pub x: Vec<f64>,
    /// Objective `½‖Ax−y‖² + λ‖x‖₁` per iteration.
    pub objective_trace: Vec<f64>,
}

/// FISTA for `min ½‖Ax − y‖₂² + lambda ‖x‖₁`.
pub fn fista(a: &dyn LinOp, y: &[f64], lambda: f64, n_iter: usize, seed: u64) -> FistaResult {
    assert_eq!(y.len(), a.rows());
    let n = a.cols();
    let lip = a.gram_norm_estimate(seed).max(1e-300);
    let step = 1.0 / lip;
    let mut x = vec![0.0; n];
    let mut z = x.clone();
    let mut t = 1.0_f64;
    let mut trace = Vec::with_capacity(n_iter);
    for _ in 0..n_iter {
        let az = a.apply(&z);
        let r: Vec<f64> = az.iter().zip(y).map(|(ai, yi)| ai - yi).collect();
        let g = a.apply_t(&r);
        let x_new: Vec<f64> = z
            .iter()
            .zip(&g)
            .map(|(zi, gi)| soft_threshold(zi - step * gi, step * lambda))
            .collect();
        let t_new = (1.0 + (1.0 + 4.0 * t * t).sqrt()) / 2.0;
        let beta = (t - 1.0) / t_new;
        z = x_new
            .iter()
            .zip(&x)
            .map(|(xn, xo)| xn + beta * (xn - xo))
            .collect();
        x = x_new;
        t = t_new;
        // objective
        let ax = a.apply(&x);
        let fit: f64 = ax
            .iter()
            .zip(y)
            .map(|(ai, yi)| (ai - yi) * (ai - yi))
            .sum::<f64>()
            * 0.5;
        let l1: f64 = x.iter().map(|v| v.abs()).sum();
        trace.push(fit + lambda * l1);
    }
    FistaResult { x, objective_trace: trace }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::rng::Rng;

    #[test]
    fn soft_threshold_cases() {
        assert_eq!(soft_threshold(3.0, 1.0), 2.0);
        assert_eq!(soft_threshold(-3.0, 1.0), -2.0);
        assert_eq!(soft_threshold(0.5, 1.0), 0.0);
        assert_eq!(soft_threshold(-0.5, 1.0), 0.0);
    }

    #[test]
    fn objective_decreases_overall() {
        let mut rng = Rng::new(141);
        let a = Mat::randn(20, 50, &mut rng);
        let y = rng.gauss_vec(20);
        let r = fista(&a, &y, 0.1, 150, 1);
        let first = r.objective_trace.first().unwrap();
        let last = r.objective_trace.last().unwrap();
        assert!(last < first, "objective did not decrease: {first} -> {last}");
    }

    #[test]
    fn large_lambda_gives_zero_solution() {
        let mut rng = Rng::new(142);
        let a = Mat::randn(10, 20, &mut rng);
        let y = rng.gauss_vec(10);
        // λ above ‖Aᵀy‖_∞ forces x = 0.
        let aty = a.matvec_t(&y);
        let lam = 1.1 * aty.iter().fold(0.0_f64, |m, v| m.max(v.abs()));
        let r = fista(&a, &y, lam, 100, 2);
        assert!(r.x.iter().all(|v| v.abs() < 1e-10));
    }

    #[test]
    fn recovers_sparse_support_with_small_lambda() {
        let mut rng = Rng::new(143);
        let a = Mat::randn(40, 60, &mut rng);
        let supp = rng.sample_indices(60, 2);
        let mut x0 = vec![0.0; 60];
        for &j in &supp {
            x0[j] = 5.0;
        }
        let y = a.matvec(&x0);
        let r = fista(&a, &y, 0.05, 400, 3);
        // The two largest coefficients should be the planted support.
        let mut idx: Vec<usize> = (0..60).collect();
        idx.sort_by(|&i, &j| r.x[j].abs().partial_cmp(&r.x[i].abs()).unwrap());
        let mut got = idx[..2].to_vec();
        got.sort_unstable();
        let mut want = supp;
        want.sort_unstable();
        assert_eq!(got, want);
    }
}
