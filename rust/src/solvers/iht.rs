//! Iterative Hard Thresholding (Blumensath & Davies).

use super::LinOp;
use crate::prox::top_k_indices;

/// Result of an IHT run.
#[derive(Clone, Debug)]
pub struct IhtResult {
    pub x: Vec<f64>,
    pub residual_norm: f64,
    pub iters: usize,
}

/// IHT: `x ← H_k(x + μ Aᵀ(y − A x))` with `μ = step / ‖A‖₂²`.
pub fn iht(a: &dyn LinOp, y: &[f64], k: usize, n_iter: usize, seed: u64) -> IhtResult {
    assert_eq!(y.len(), a.rows());
    let n = a.cols();
    let gram = a.gram_norm_estimate(seed).max(1e-300);
    let mu = 0.99 / gram;
    let mut x = vec![0.0; n];
    let mut iters = 0;
    for _ in 0..n_iter {
        let ax = a.apply(&x);
        let r: Vec<f64> = y.iter().zip(&ax).map(|(yi, ai)| yi - ai).collect();
        let g = a.apply_t(&r);
        let mut z: Vec<f64> = x.iter().zip(&g).map(|(xi, gi)| xi + mu * gi).collect();
        // Hard threshold: keep top-k.
        let keep = top_k_indices(&z, k);
        let keep_set: std::collections::HashSet<usize> = keep.into_iter().collect();
        for (j, v) in z.iter_mut().enumerate() {
            if !keep_set.contains(&j) {
                *v = 0.0;
            }
        }
        // Convergence check.
        let delta: f64 = x
            .iter()
            .zip(&z)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt();
        x = z;
        iters += 1;
        if delta < 1e-12 {
            break;
        }
    }
    let ax = a.apply(&x);
    let residual_norm = y
        .iter()
        .zip(&ax)
        .map(|(yi, ai)| (yi - ai) * (yi - ai))
        .sum::<f64>()
        .sqrt();
    IhtResult { x, residual_norm, iters }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::rng::Rng;

    #[test]
    fn iht_recovers_on_orthogonal_dictionary() {
        let h = crate::transforms::hadamard(16);
        let mut rng = Rng::new(131);
        let supp = rng.sample_indices(16, 3);
        let mut x = vec![0.0; 16];
        for &j in &supp {
            x[j] = 1.5 + rng.uniform();
        }
        let y = h.matvec(&x);
        let r = iht(&h, &y, 3, 200, 1);
        assert!(r.residual_norm < 1e-8, "resid={}", r.residual_norm);
    }

    #[test]
    fn iht_sparsity_is_enforced() {
        let mut rng = Rng::new(132);
        let a = Mat::randn(15, 30, &mut rng);
        let y = rng.gauss_vec(15);
        let r = iht(&a, &y, 4, 100, 2);
        assert!(r.x.iter().filter(|v| **v != 0.0).count() <= 4);
    }

    #[test]
    fn iht_on_gaussian_recovers_well_separated_sparse() {
        let mut rng = Rng::new(133);
        let a = Mat::randn(40, 80, &mut rng);
        let supp = rng.sample_indices(80, 3);
        let mut x = vec![0.0; 80];
        for &j in &supp {
            x[j] = 3.0 + rng.uniform();
        }
        let y = a.matvec(&x);
        let r = iht(&a, &y, 3, 500, 3);
        // Support recovery.
        let mut got: Vec<usize> = r
            .x
            .iter()
            .enumerate()
            .filter(|(_, v)| **v != 0.0)
            .map(|(i, _)| i)
            .collect();
        got.sort_unstable();
        let mut want = supp;
        want.sort_unstable();
        assert_eq!(got, want);
    }
}
