//! Orthogonal Matching Pursuit (Tropp & Gilbert) over a [`LinOp`].
//!
//! Greedy: pick the atom most correlated with the residual, re-fit by least
//! squares on the selected support, repeat `k` times. The cost is dominated
//! by `Aᵀ r` per iteration — exactly the product the paper accelerates with
//! FAμSTs (§V-B: "the computational cost of OMP is dominated by products
//! with Mᵀ").
//!
//! Note the paper's §VI-C remark: when the dictionary is a FAμST, columns
//! are not unit-norm and plain correlation yields a "weighted OMP"; we
//! reproduce that behaviour by default and expose optional column-norm
//! compensation.

use super::LinOp;
use crate::linalg::{lstsq, Mat};

/// Result of one OMP solve.
#[derive(Clone, Debug)]
pub struct OmpResult {
    /// Selected atom indices, in selection order.
    pub support: Vec<usize>,
    /// Coefficients aligned with `support`.
    pub coefs: Vec<f64>,
    /// Final residual l2 norm.
    pub residual_norm: f64,
}

impl OmpResult {
    /// Densify the sparse code into a length-`n` vector.
    pub fn dense_code(&self, n: usize) -> Vec<f64> {
        let mut x = vec![0.0; n];
        for (&j, &c) in self.support.iter().zip(&self.coefs) {
            x[j] = c;
        }
        x
    }
}

/// Run OMP: approximate `y ≈ A x` with `‖x‖₀ ≤ k`.
///
/// `col_norms`: pass `Some(norms)` to normalize the correlation step by
/// per-column norms (classical OMP on non-normalized dictionaries); `None`
/// reproduces the paper's plain/"weighted" variant.
pub fn omp(a: &dyn LinOp, y: &[f64], k: usize, col_norms: Option<&[f64]>) -> OmpResult {
    assert_eq!(y.len(), a.rows(), "omp: y dim mismatch");
    let n = a.cols();
    let k = k.min(n);
    let mut support: Vec<usize> = Vec::with_capacity(k);
    let mut selected = vec![false; n];
    let mut residual = y.to_vec();
    let mut atoms = Mat::zeros(a.rows(), 0); // selected atoms, grown by column
    let mut coefs: Vec<f64> = vec![];
    for _ in 0..k {
        // Correlation step: c = Aᵀ r  (the hot product).
        let corr = a.apply_t(&residual);
        let mut best = None;
        let mut best_val = 0.0;
        for j in 0..n {
            if selected[j] {
                continue;
            }
            let mut v = corr[j].abs();
            if let Some(norms) = col_norms {
                if norms[j] > 1e-300 {
                    v /= norms[j];
                } else {
                    continue;
                }
            }
            if v > best_val {
                best_val = v;
                best = Some(j);
            }
        }
        let Some(j) = best else { break };
        if best_val <= 1e-300 {
            break; // residual orthogonal to every remaining atom
        }
        selected[j] = true;
        support.push(j);
        // Grow the atom matrix.
        let col = a.column(j);
        let mut grown = Mat::zeros(a.rows(), support.len());
        for c in 0..support.len() - 1 {
            for i in 0..a.rows() {
                grown.set(i, c, atoms.at(i, c));
            }
        }
        for i in 0..a.rows() {
            grown.set(i, support.len() - 1, col[i]);
        }
        atoms = grown;
        // Least-squares re-fit on the support.
        coefs = lstsq(&atoms, y);
        // Residual r = y − A_S x_S.
        let yhat = atoms.matvec(&coefs);
        for i in 0..y.len() {
            residual[i] = y[i] - yhat[i];
        }
    }
    let residual_norm = residual.iter().map(|v| v * v).sum::<f64>().sqrt();
    OmpResult { support, coefs, residual_norm }
}

/// Batch-code every column of `y` against dictionary `d` with `k` atoms
/// each; returns the coefficient matrix `Γ` (`d.cols() × y.cols()`).
pub fn omp_batch(d: &Mat, y: &Mat, k: usize) -> Mat {
    let mut gamma = Mat::zeros(d.cols(), y.cols());
    // Precompute column norms once (classic batch OMP behaviour).
    let norms: Vec<f64> = (0..d.cols())
        .map(|j| d.col(j).iter().map(|x| x * x).sum::<f64>().sqrt())
        .collect();
    for c in 0..y.cols() {
        let yc = y.col(c);
        let r = omp(d, &yc, k, Some(&norms));
        for (&j, &v) in r.support.iter().zip(&r.coefs) {
            gamma.set(j, c, v);
        }
    }
    gamma
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn exact_recovery_on_orthogonal_dictionary() {
        // With an orthogonal dictionary OMP recovers any k-sparse signal
        // exactly in k steps.
        let h = crate::transforms::hadamard(16);
        let mut rng = Rng::new(121);
        for _ in 0..10 {
            let supp = rng.sample_indices(16, 3);
            let mut x = vec![0.0; 16];
            for &j in &supp {
                x[j] = rng.gauss() + 2.0; // bounded away from 0
            }
            let y = h.matvec(&x);
            let r = omp(&h, &y, 3, None);
            assert!(r.residual_norm < 1e-10);
            let mut got = r.support.clone();
            got.sort_unstable();
            let mut want = supp.clone();
            want.sort_unstable();
            assert_eq!(got, want);
        }
    }

    #[test]
    fn recovery_via_faust_matches_dense() {
        // Same dictionary as dense Mat and as exact FAμST: identical picks.
        let h = crate::transforms::hadamard(32);
        let hf = crate::transforms::hadamard_faust(32);
        let mut rng = Rng::new(122);
        let supp = rng.sample_indices(32, 2);
        let mut x = vec![0.0; 32];
        for &j in &supp {
            x[j] = 1.0 + rng.uniform();
        }
        let y = h.matvec(&x);
        let rd = omp(&h, &y, 2, None);
        let rf = omp(&hf, &y, 2, None);
        let mut sd = rd.support.clone();
        let mut sf = rf.support.clone();
        sd.sort_unstable();
        sf.sort_unstable();
        assert_eq!(sd, sf);
        assert!(rf.residual_norm < 1e-9);
    }

    #[test]
    fn residual_norm_decreases_with_k() {
        let mut rng = Rng::new(123);
        let a = Mat::randn(20, 40, &mut rng);
        let y = rng.gauss_vec(20);
        let mut prev = f64::INFINITY;
        for k in 1..=6 {
            let r = omp(&a, &y, k, None);
            assert!(r.residual_norm <= prev + 1e-12);
            prev = r.residual_norm;
        }
    }

    #[test]
    fn dense_code_roundtrip() {
        let mut rng = Rng::new(124);
        let a = Mat::randn(10, 15, &mut rng);
        let y = rng.gauss_vec(10);
        let r = omp(&a, &y, 4, None);
        let x = r.dense_code(15);
        assert_eq!(x.iter().filter(|v| **v != 0.0).count(), r.support.len());
    }

    #[test]
    fn omp_batch_shapes_and_sparsity() {
        let mut rng = Rng::new(125);
        let d = Mat::randn(8, 20, &mut rng);
        let y = Mat::randn(8, 5, &mut rng);
        let g = omp_batch(&d, &y, 3);
        assert_eq!(g.shape(), (20, 5));
        for c in 0..5 {
            let nnz = g.col(c).iter().filter(|v| **v != 0.0).count();
            assert!(nnz <= 3);
        }
    }

    #[test]
    fn zero_signal_gives_empty_support() {
        let mut rng = Rng::new(126);
        let a = Mat::randn(6, 9, &mut rng);
        let r = omp(&a, &[0.0; 6], 3, None);
        assert!(r.support.is_empty());
        assert_eq!(r.residual_norm, 0.0);
    }
}
