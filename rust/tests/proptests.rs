//! Property-based test sweeps (seeded generators; failures report the
//! case seed — see `faust::testutil`).

use faust::engine::{
    par_spmm_into, ApplyEngine, EngineConfig, ExecCtx, FleetCtx, PlanConfig, ThreadPool,
};
use faust::faust::Faust;
use faust::hierarchical::{
    factorize_fleet_with_ctx, factorize_with_ctx, HierarchicalConfig,
};
use faust::linalg::{chain_product, lstsq, qr_thin, svd_jacobi, Mat};
use faust::prox::{proj_sp, proj_spcol, proj_sprow, Constraint};
use faust::palm::{palm4msa, palm4msa_with_ctx, FactorState, PalmConfig};
use faust::sparse::{Coo, Csr};
use faust::testutil::{check, ensure, faust_fingerprint, gen, PropConfig};

fn cfg(cases: usize) -> PropConfig {
    PropConfig { cases, base_seed: 0xBEEF }
}

#[test]
fn prop_spmv_equals_dense_matvec() {
    check("spmv == dense", &cfg(100), |rng| {
        let r = 1 + rng.below(20);
        let c = 1 + rng.below(20);
        let nnz = rng.below(r * c + 1);
        let d = gen::sparse_mat(rng, r, c, nnz);
        let s = Csr::from_dense(&d, 0.0);
        let x = rng.gauss_vec(c);
        let yd = d.matvec(&x);
        let ys = s.spmv(&x);
        for i in 0..r {
            ensure((yd[i] - ys[i]).abs() < 1e-10, format!("row {i}"))?;
        }
        Ok(())
    });
}

#[test]
fn prop_coo_csr_roundtrip() {
    check("coo<->csr roundtrip", &cfg(100), |rng| {
        let r = 1 + rng.below(15);
        let c = 1 + rng.below(15);
        let nnz2 = rng.below(r * c + 1);
        let d = gen::sparse_mat(rng, r, c, nnz2);
        let coo = Coo::from_dense(&d, 0.0);
        let csr = Csr::from_coo(&coo);
        ensure(csr.to_dense().rel_fro_err(&d) < 1e-14, "roundtrip mismatch")?;
        ensure(csr.nnz() == d.nnz(), "nnz mismatch")?;
        ensure(
            csr.transpose().to_dense().rel_fro_err(&d.t()) < 1e-14,
            "transpose mismatch",
        )
    });
}

#[test]
fn prop_projection_feasible_idempotent_and_contractive() {
    check("projection properties", &cfg(60), |rng| {
        let u = gen::mat(rng, 10);
        let (r, c) = u.shape();
        let k = 1 + rng.below(r * c);
        let candidates = vec![
            Constraint::SpGlobal(k),
            Constraint::SpCol(1 + rng.below(r)),
            Constraint::SpRow(1 + rng.below(c)),
        ];
        for cst in candidates {
            let p = cst.project(&u);
            ensure(cst.is_feasible(&p, 1e-9), format!("infeasible {cst:?}"))?;
            let p2 = cst.project(&p);
            ensure(p2.rel_fro_err(&p) < 1e-10, format!("not idempotent {cst:?}"))?;
        }
        Ok(())
    });
}

#[test]
fn prop_proj_sp_optimality() {
    // Projection is closer to U than any random feasible point.
    check("proj_sp optimal", &cfg(40), |rng| {
        let u = gen::mat_shaped(rng, 5, 6);
        let s = 1 + rng.below(12);
        let p = proj_sp(&u, s);
        let d_star = p.sub(&u).fro();
        for _ in 0..30 {
            let mut cand = gen::sparse_mat(rng, 5, 6, s);
            let f = cand.fro();
            if f == 0.0 {
                continue;
            }
            cand.scale(1.0 / f);
            ensure(
                d_star <= cand.sub(&u).fro() + 1e-9,
                "found closer feasible point",
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_rowcol_budgets_respected() {
    check("row/col budgets", &cfg(60), |rng| {
        let u = gen::mat(rng, 12);
        let k = 1 + rng.below(4);
        let pc = proj_spcol(&u, k);
        for j in 0..pc.cols() {
            let nz = pc.col(j).iter().filter(|v| **v != 0.0).count();
            ensure(nz <= k, format!("col {j} has {nz} > {k}"))?;
        }
        let pr = proj_sprow(&u, k);
        for i in 0..pr.rows() {
            let nz = pr.row(i).iter().filter(|v| **v != 0.0).count();
            ensure(nz <= k, format!("row {i} has {nz} > {k}"))?;
        }
        Ok(())
    });
}

#[test]
fn prop_palm_objective_monotone() {
    check("palm monotone descent", &cfg(15), |rng| {
        let n = 4 + rng.below(5);
        let a = gen::mat_shaped(rng, n, n);
        let budget = n + rng.below(n * n - n);
        let cfg = PalmConfig::new(
            vec![Constraint::SpGlobal(budget), Constraint::SpGlobal(budget)],
            20,
        );
        let res = palm4msa(&a, FactorState::default_init(&[(n, n), (n, n)]), &cfg);
        for w in res.objective_trace.windows(2) {
            ensure(
                w[1] <= w[0] * (1.0 + 1e-7) + 1e-10,
                format!("ascent {} -> {}", w[0], w[1]),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_faust_apply_linear() {
    // apply(ax + by) == a·apply(x) + b·apply(y).
    check("faust linearity", &cfg(40), |rng| {
        let depth = 1 + rng.below(4);
        let mut dims = vec![1 + rng.below(10)];
        for _ in 0..depth {
            dims.push(1 + rng.below(10));
        }
        let mats: Vec<Mat> = (0..depth)
            .map(|i| {
                let nz = 1 + rng.below(dims[i + 1] * dims[i]);
                gen::sparse_mat(rng, dims[i + 1], dims[i], nz)
            })
            .collect();
        let f = Faust::from_dense_factors(&mats, rng.range(0.3, 2.0));
        let x = rng.gauss_vec(f.cols());
        let y = rng.gauss_vec(f.cols());
        let (a, b) = (rng.gauss(), rng.gauss());
        let mixed: Vec<f64> = x.iter().zip(&y).map(|(xi, yi)| a * xi + b * yi).collect();
        let lhs = f.apply(&mixed);
        let fx = f.apply(&x);
        let fy = f.apply(&y);
        for i in 0..f.rows() {
            let rhs = a * fx[i] + b * fy[i];
            ensure((lhs[i] - rhs).abs() < 1e-9 * (1.0 + rhs.abs()), "not linear")?;
        }
        Ok(())
    });
}

#[test]
fn prop_faust_transpose_adjoint() {
    // <Fx, y> == <x, Fᵀy> — the adjoint identity the solvers rely on.
    check("adjoint identity", &cfg(40), |rng| {
        let mats = vec![
            gen::sparse_mat(rng, 6, 8, 20),
            gen::sparse_mat(rng, 5, 6, 15),
        ];
        let f = Faust::from_dense_factors(&mats, 1.3);
        let x = rng.gauss_vec(8);
        let y = rng.gauss_vec(5);
        let fx = f.apply(&x);
        let fty = f.apply_t(&y);
        let lhs: f64 = fx.iter().zip(&y).map(|(a, b)| a * b).sum();
        let rhs: f64 = x.iter().zip(&fty).map(|(a, b)| a * b).sum();
        ensure((lhs - rhs).abs() < 1e-9 * (1.0 + lhs.abs()), format!("{lhs} != {rhs}"))
    });
}

#[test]
fn prop_qr_and_svd_reconstruct() {
    check("qr/svd reconstruct", &cfg(25), |rng| {
        let m = 2 + rng.below(10);
        let n = 2 + rng.below(10);
        let a = gen::mat_shaped(rng, m, n);
        let (q, r) = qr_thin(&a);
        ensure(q.matmul(&r).rel_fro_err(&a) < 1e-10, "qr reconstruct")?;
        let svd = svd_jacobi(&a);
        ensure(svd.reconstruct().rel_fro_err(&a) < 1e-8, "svd reconstruct")?;
        // Least squares residual is orthogonal to the column space
        // (lstsq is defined for overdetermined systems: transpose if needed).
        let a = if m >= n { a } else { a.t() };
        let m = a.rows();
        let b = rng.gauss_vec(m);
        let x = lstsq(&a, &b);
        let ax = a.matvec(&x);
        let resid: Vec<f64> = b.iter().zip(&ax).map(|(bi, ai)| bi - ai).collect();
        let back = a.matvec_t(&resid);
        let bn: f64 = back.iter().map(|v| v * v).sum::<f64>().sqrt();
        let scale: f64 = 1.0 + b.iter().map(|v| v * v).sum::<f64>().sqrt();
        ensure(bn < 1e-7 * scale, format!("normal equations violated: {bn}"))
    });
}

/// Random rightmost-first factor chain + its dense reference λ·S_J⋯S_1.
fn gen_chain(rng: &mut faust::rng::Rng) -> (Faust, Mat) {
    let depth = 1 + rng.below(4);
    let mut dims = vec![2 + rng.below(9)];
    for _ in 0..depth {
        dims.push(2 + rng.below(9));
    }
    let mats: Vec<Mat> = (0..depth)
        .map(|i| {
            let (r, c) = (dims[i + 1], dims[i]);
            let nz = 1 + rng.below(r * c);
            gen::sparse_mat(rng, r, c, nz)
        })
        .collect();
    let lambda = rng.range(0.2, 2.5);
    let refs: Vec<&Mat> = mats.iter().rev().collect();
    let dense = chain_product(&refs, dims[0]).scaled(lambda);
    (Faust::from_dense_factors(&mats, lambda), dense)
}

#[test]
fn prop_tiled_gemm_matches_scalar_reference() {
    // ISSUE 5: the register-tiled microkernel must agree with the scalar
    // reference within 1e-12 across shapes, including lane-remainder
    // column counts (n not a multiple of 4/8), sub-tile row counts, and
    // sparse operands (the tiled zero-skip groups rows per MR tile).
    use faust::engine::kernel;
    check("tiled gemm == scalar reference", &cfg(80), |rng| {
        let m = 1 + rng.below(45);
        let k = 1 + rng.below(40);
        let n = 1 + rng.below(21);
        let nnz = rng.below(m * k + 1);
        let a = gen::sparse_mat(rng, m, k, nnz);
        let b = Mat::randn(k, n, rng);
        let mut want = vec![0.0; m * n];
        kernel::gemm_scalar_rows(&a, b.data(), n, 0, m, &mut want);
        let mut got = vec![0.0; m * n];
        kernel::gemm_tiled_rows(&a, b.data(), n, 0, m, &mut got);
        for (idx, (g, w)) in got.iter().zip(&want).enumerate() {
            ensure(
                (g - w).abs() <= 1e-12 * (1.0 + w.abs()),
                format!("({m},{k},{n}) entry {idx}: {g} vs {w}"),
            )?;
        }
        // The transposed-matvec kernel is held to the stricter bitwise
        // bar (its per-element accumulation order is unchanged).
        let x = rng.gauss_vec(m);
        let mut tv_want = vec![0.0; k];
        kernel::gemv_t_scalar_cols(&a, &x, 0, k, &mut tv_want);
        let mut tv_got = vec![0.0; k];
        kernel::gemv_t_tiled_cols(&a, &x, 0, k, &mut tv_got);
        for (idx, (g, w)) in tv_got.iter().zip(&tv_want).enumerate() {
            ensure(g.to_bits() == w.to_bits(), format!("gemv_t col {idx}"))?;
        }
        Ok(())
    });
}

#[test]
fn prop_tiled_kernels_bitwise_thread_invariant() {
    // ISSUE 5: the new kernels must keep the engine's bitwise
    // thread-invariance contract across {1, 2, 8} threads, for both GEMM
    // dispatch branches and the pooled transposed matvec.
    use faust::engine::{par_gemv_t_into, ThreadPool};
    let serial = ExecCtx::serial();
    let pooled = [ExecCtx::new(2), ExecCtx::new(8)];
    let pools = [ThreadPool::new(2), ThreadPool::new(8)];
    check("tiled kernels thread-invariant", &cfg(30), |rng| {
        let m = 1 + rng.below(60);
        let k = 1 + rng.below(40);
        let n = 1 + rng.below(30);
        // Sparse a, dense b: exercises both rewrite branches over cases.
        let a = gen::sparse_mat(rng, m, k, 1 + rng.below(m * k));
        let b = Mat::randn(k, n, rng);
        let base = serial.gemm(&a, &b);
        for ctx in &pooled {
            let got = ctx.gemm(&a, &b);
            ensure(
                got.data()
                    .iter()
                    .zip(base.data())
                    .all(|(g, w)| g.to_bits() == w.to_bits()),
                format!("gemm bits drift at {} threads", ctx.n_threads()),
            )?;
        }
        let x = rng.gauss_vec(m);
        let mut base_t = vec![0.0; k];
        par_gemv_t_into(serial.pool(), &a, &x, &mut base_t);
        for pool in &pools {
            let mut got_t = vec![0.0; k];
            par_gemv_t_into(pool, &a, &x, &mut got_t);
            ensure(
                got_t
                    .iter()
                    .zip(&base_t)
                    .all(|(g, w)| g.to_bits() == w.to_bits()),
                format!("gemv_t bits drift at {} threads", pool.n_threads()),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_parallel_spmm_equals_serial() {
    let pool = ThreadPool::new(4);
    check("parallel spmm == serial spmm", &cfg(60), |rng| {
        let r = 1 + rng.below(40);
        let c = 1 + rng.below(40);
        let nnz = rng.below(r * c + 1);
        let b = 1 + rng.below(9);
        let d = gen::sparse_mat(rng, r, c, nnz);
        let s = Csr::from_dense(&d, 0.0);
        let x = Mat::randn(c, b, rng);
        let want = s.spmm(&x);
        let mut got = vec![0.0; r * b];
        par_spmm_into(&pool, &s, x.data(), b, &mut got);
        for (i, (g, w)) in got.iter().zip(want.data()).enumerate() {
            ensure((g - w).abs() < 1e-10, format!("entry {i}: {g} vs {w}"))?;
        }
        Ok(())
    });
}

#[test]
fn prop_planned_apply_matches_naive_dense_reference() {
    // Planned apply (fusion + strategy selection + pooled kernels) must
    // equal the dense reference λ·S_J⋯S_1 within 1e-10 relative
    // Frobenius error, for both forward and transpose, serial and pooled.
    let engines = [
        ApplyEngine::serial(),
        ApplyEngine::new(EngineConfig { n_threads: 4, plan: PlanConfig::default() }),
        ApplyEngine::new(EngineConfig {
            n_threads: 2,
            plan: PlanConfig { fuse: false, dense_threshold: 0.1, ..PlanConfig::default() },
        }),
    ];
    check("planned apply == dense reference", &cfg(40), |rng| {
        let (f, dense) = gen_chain(rng);
        let b = 1 + rng.below(6);
        let x = Mat::randn(f.cols(), b, rng);
        let want = dense.matmul(&x);
        let xt = Mat::randn(f.rows(), b, rng);
        let want_t = dense.t().matmul(&xt);
        for engine in &engines {
            let op = engine.op(&f);
            let got = op.apply_batch(&x);
            let fwd_err = got.sub(&want).fro();
            ensure(
                fwd_err < 1e-10 * (1.0 + want.fro()),
                format!("forward mismatch: {fwd_err}"),
            )?;
            let got_t = op.apply_t_batch(&xt);
            let t_err = got_t.sub(&want_t).fro();
            ensure(
                t_err < 1e-10 * (1.0 + want_t.fro()),
                format!("transpose mismatch: {t_err}"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_faust_apply_routes_through_plan_consistently() {
    // Faust::apply / apply_mat (cached-plan paths) agree with the dense
    // reference and with each other, column by column.
    check("faust planned paths consistent", &cfg(40), |rng| {
        let (f, dense) = gen_chain(rng);
        let x = rng.gauss_vec(f.cols());
        let y = f.apply(&x);
        let want = dense.matvec(&x);
        for i in 0..f.rows() {
            ensure(
                (y[i] - want[i]).abs() < 1e-10 * (1.0 + want[i].abs()),
                format!("apply row {i}"),
            )?;
        }
        let xm = Mat::randn(f.cols(), 3, rng);
        let ym = f.apply_mat(&xm);
        for j in 0..3 {
            let col = f.apply(&xm.col(j));
            for i in 0..f.rows() {
                ensure((ym.at(i, j) - col[i]).abs() < 1e-12, format!("batch col {j} row {i}"))?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_ctx_palm4msa_thread_invariant() {
    // ISSUE 2: ctx-parallel palm4MSA equals the serial path within 1e-10
    // relative Frobenius error across thread counts {1, 2, 8}.
    let serial = ExecCtx::serial();
    let pooled = [ExecCtx::new(2), ExecCtx::new(8)];
    check("ctx palm4msa thread-invariant", &cfg(10), |rng| {
        let n = 4 + rng.below(5);
        let a = gen::mat_shaped(rng, n, n);
        let budget = n + rng.below(n * n - n);
        let pcfg = PalmConfig::new(
            vec![Constraint::SpGlobal(budget), Constraint::SpGlobal(budget)],
            12,
        );
        let dims = [(n, n), (n, n)];
        let base = palm4msa_with_ctx(&serial, &a, FactorState::default_init(&dims), &pcfg);
        for ctx in &pooled {
            let res = palm4msa_with_ctx(ctx, &a, FactorState::default_init(&dims), &pcfg);
            let dl = (res.state.lambda - base.state.lambda).abs();
            ensure(
                dl <= 1e-10 * (1.0 + base.state.lambda.abs()),
                format!("lambda drift {dl} at {} threads", ctx.n_threads()),
            )?;
            for (m1, m2) in res.state.mats.iter().zip(&base.state.mats) {
                let d = m1.sub(m2).fro();
                ensure(
                    d <= 1e-10 * (1.0 + m2.fro()),
                    format!("factor drift {d} at {} threads", ctx.n_threads()),
                )?;
            }
            let dp = res.product.sub(&base.product).fro();
            ensure(
                dp <= 1e-10 * (1.0 + base.product.fro()),
                format!("cached product drift {dp}"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_ctx_hierarchical_thread_invariant() {
    // ISSUE 2: ctx-parallel hierarchical::factorize equals the serial
    // path within 1e-10 relative Frobenius error for threads {1, 2, 8}.
    let serial = ExecCtx::serial();
    let pooled = [ExecCtx::new(2), ExecCtx::new(8)];
    check("ctx hierarchical thread-invariant", &cfg(5), |rng| {
        let a = gen::mat_shaped(rng, 12, 12);
        let mut hcfg = HierarchicalConfig::meg(12, 12, 3, 4, 30, 0.8, 60.0);
        hcfg.n_iter_split = 15;
        hcfg.n_iter_global = 8;
        hcfg.seed = rng.below(1 << 20) as u64;
        let base = factorize_with_ctx(&serial, &a, &hcfg).to_dense();
        for ctx in &pooled {
            let got = factorize_with_ctx(ctx, &a, &hcfg).to_dense();
            let d = got.sub(&base).fro();
            ensure(
                d <= 1e-10 * (1.0 + base.fro()),
                format!("drift {d} at {} threads", ctx.n_threads()),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_factorize_fleet_bitwise_identical_to_independent_runs() {
    // ISSUE 4: `factorize_fleet` of N operators must be bitwise identical
    // to N independent `factorize_with_ctx` runs, at every thread count
    // in {1, 2, 8}. Fleets are randomized: member count, operator
    // contents, level counts and seeds all vary per case.
    let ctxs = [ExecCtx::serial(), ExecCtx::new(2), ExecCtx::new(8)];
    check("factorize_fleet == independent runs", &cfg(4), |rng| {
        let n_ops = 2 + rng.below(2); // 2..=3 members
        let mut targets: Vec<Mat> = Vec::new();
        let mut cfgs: Vec<HierarchicalConfig> = Vec::new();
        for k in 0..n_ops {
            let n = 10 + rng.below(5);
            targets.push(gen::mat_shaped(rng, n, n));
            let j = 2 + rng.below(2); // 2..=3 levels+1
            let mut hcfg = HierarchicalConfig::meg(n, n, j, 4, 3 * n, 0.8, (5 * n) as f64);
            hcfg.n_iter_split = 8;
            hcfg.n_iter_global = 5;
            hcfg.seed = rng.below(1 << 20) as u64 ^ k as u64;
            cfgs.push(hcfg);
        }
        let jobs: Vec<(&Mat, &HierarchicalConfig)> =
            targets.iter().zip(&cfgs).collect();
        for ctx in &ctxs {
            let solo: Vec<(u64, Vec<Vec<u64>>)> = jobs
                .iter()
                .map(|&(a, c)| faust_fingerprint(&factorize_with_ctx(ctx, a, c)))
                .collect();
            let fleet = FleetCtx::new(ctx.clone());
            let got = factorize_fleet_with_ctx(&fleet, &jobs);
            for (k, (g, w)) in got.iter().zip(&solo).enumerate() {
                ensure(
                    &faust_fingerprint(g) == w,
                    format!(
                        "member {k} diverged at {} threads",
                        ctx.n_threads()
                    ),
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn hierarchical_factorization_is_bitwise_deterministic_across_threads() {
    // ISSUE 2 determinism: same seed ⇒ identical factor bits regardless
    // of the thread count (every ctx kernel accumulates each output
    // element in a fixed order).
    let a = faust::transforms::hadamard(16);
    let hcfg = HierarchicalConfig::hadamard(16);
    let base = faust_fingerprint(&factorize_with_ctx(&ExecCtx::serial(), &a, &hcfg));
    for threads in [2usize, 8] {
        let got = faust_fingerprint(&factorize_with_ctx(&ExecCtx::new(threads), &a, &hcfg));
        assert_eq!(base, got, "{threads} threads changed the factorization bits");
    }
}

#[test]
fn prop_rc_accounting_matches_counts() {
    check("rc accounting", &cfg(40), |rng| {
        let nz1 = 1 + rng.below(40);
        let nz2 = 1 + rng.below(30);
        let mats = vec![
            gen::sparse_mat(rng, 7, 9, nz1),
            gen::sparse_mat(rng, 6, 7, nz2),
        ];
        let nnz_total: usize = mats.iter().map(|m| m.nnz()).sum();
        let f = Faust::from_dense_factors(&mats, 1.0);
        ensure(f.s_tot() == nnz_total, "s_tot mismatch")?;
        let rc = nnz_total as f64 / (6.0 * 9.0);
        ensure((f.rc() - rc).abs() < 1e-12, "rc mismatch")?;
        ensure(f.flops_per_matvec() == 2 * nnz_total, "flops mismatch")
    });
}

// ISSUE 6: wire-protocol properties (server::wire).

#[test]
fn prop_wire_request_roundtrips_across_shapes_and_classes() {
    use faust::coordinator::QosClass;
    use faust::server::wire::{self, WireRequest};
    check("wire request roundtrip", &cfg(120), |rng| {
        let rows = rng.below(33); // 0 rows is a legal (degenerate) shape
        let cols = rng.below(9);
        let name_len = 1 + rng.below(24);
        let op: String = (0..name_len)
            .map(|_| (b'a' + rng.below(26) as u8) as char)
            .collect();
        let class = QosClass::from_u8(rng.below(3) as u8).unwrap();
        let req = WireRequest {
            req_id: rng.below(1 << 30) as u64,
            op,
            class,
            deadline_us: rng.below(1 << 20) as u32,
            dtype: wire::Dtype::F64,
            version: wire::VERSION,
            rows,
            cols,
            data: rng.gauss_vec(rows * cols),
        };
        // encode_* returns the full frame (length prefix included);
        // decode_* takes the body with the prefix already stripped.
        let frame = wire::encode_request(&req);
        let back = wire::decode_request(&frame[4..]).map_err(|e| format!("decode: {e}"))?;
        ensure(back == req, "request did not roundtrip")?;
        // And through framed IO: read_frame strips the prefix back off.
        let mut buf = Vec::new();
        wire::write_frame(&mut buf, &frame).map_err(|e| format!("write: {e}"))?;
        let mut cur = std::io::Cursor::new(buf);
        let read = wire::read_frame(&mut cur)
            .map_err(|e| format!("read: {e}"))?
            .ok_or("unexpected EOF")?;
        ensure(read == frame[4..], "framed body mismatch")
    });
}

#[test]
fn prop_wire_truncation_is_a_typed_rejection_never_a_panic() {
    use faust::coordinator::QosClass;
    use faust::server::wire::{self, WireRequest};
    check("wire truncation typed", &cfg(80), |rng| {
        let rows = 1 + rng.below(8);
        let cols = 1 + rng.below(4);
        let req = WireRequest {
            req_id: 7,
            op: "op".to_string(),
            class: QosClass::from_u8(rng.below(3) as u8).unwrap(),
            deadline_us: 0,
            dtype: wire::Dtype::F64,
            version: wire::VERSION,
            rows,
            cols,
            data: rng.gauss_vec(rows * cols),
        };
        let framed = wire::encode_request(&req);
        let body = &framed[4..]; // length prefix stripped, as read_frame would
        // Any strict prefix of the body must decode to a typed error.
        let cut = rng.below(body.len());
        ensure(
            wire::decode_request(&body[..cut]).is_err(),
            format!("prefix of {cut} bytes decoded"),
        )?;
        // A frame cut mid-stream surfaces as a typed read error (or a
        // clean EOF when nothing was sent), never a panic.
        let fcut = rng.below(framed.len()); // strictly before the last byte
        let mut cur = std::io::Cursor::new(&framed[..fcut]);
        match wire::read_frame(&mut cur) {
            Ok(None) => ensure(fcut == 0, "EOF only legal at a frame boundary")?,
            Ok(Some(_)) => return Err("truncated frame returned a body".into()),
            Err(e) => ensure(!format!("{e}").is_empty(), "error displays")?,
        }
        Ok(())
    });
}

#[test]
fn prop_wire_response_roundtrips() {
    use faust::server::wire::{self, ErrorCode, WireResponse};
    check("wire response roundtrip", &cfg(80), |rng| {
        let resp = if rng.uniform() < 0.5 {
            let rows = rng.below(16);
            let cols = rng.below(4);
            WireResponse::Ok {
                req_id: rng.below(1 << 30) as u64,
                epoch: rng.below(1 << 20) as u64,
                rows,
                cols,
                dtype: wire::Dtype::F64,
                data: rng.gauss_vec(rows * cols),
            }
        } else {
            let codes = [
                ErrorCode::UnknownOperator,
                ErrorCode::WrongDimension,
                ErrorCode::Overloaded,
                ErrorCode::ShuttingDown,
                ErrorCode::Malformed,
            ];
            WireResponse::Err {
                req_id: rng.below(1 << 30) as u64,
                code: codes[rng.below(codes.len())],
                msg: format!("case {}", rng.below(1000)),
            }
        };
        // f64 responses round-trip identically under both wire versions
        // (v1 has no dtype byte and implies f64).
        let version = 1 + rng.below(2) as u8;
        let frame = wire::encode_response(&resp, version);
        let back = wire::decode_response(&frame[4..]).map_err(|e| format!("decode: {e}"))?;
        ensure(back == resp, "response did not roundtrip")
    });
}

// ISSUE 7: f32 mixed-precision serving tier properties.

#[test]
fn prop_f32_plan_within_declared_bound_and_bitwise_thread_invariant() {
    use faust::engine::Arena;
    check("f32 plan bound + thread invariance", &cfg(25), |rng| {
        // Chain shapes deliberately straddle the f32 lane widths (16/8/8)
        // so remainder loops are exercised alongside full lane chunks.
        let d0 = 1 + rng.below(37);
        let d1 = 1 + rng.below(37);
        let d2 = 1 + rng.below(37);
        let mats = vec![
            gen::sparse_mat(rng, d1, d0, 1 + rng.below(d1 * d0)),
            gen::sparse_mat(rng, d2, d1, 1 + rng.below(d2 * d1)),
        ];
        let f = Faust::from_dense_factors(&mats, 1.0 + rng.uniform());
        let plan = faust::engine::ApplyPlan::compile(&f, &PlanConfig::default());
        let pool1 = ThreadPool::new(1);
        let (plan32, bound) = plan.to_f32_with_bound(&pool1);
        ensure(bound.declared_rel_err > 0.0, "declared bound must be positive")?;
        ensure(
            bound.measured_rel_err <= bound.declared_rel_err,
            "measured exceeds declared",
        )?;

        let bcols = 1 + rng.below(3);
        let x64 = rng.gauss_vec(d0 * bcols);
        let x32: Vec<f32> = x64.iter().map(|&v| v as f32).collect();
        let mut y64 = vec![0.0f64; d2 * bcols];
        let mut a64 = Arena::<f64>::new();
        plan.execute_batch_into(&pool1, &mut a64, &x64, bcols, &mut y64);

        let mut base32: Option<Vec<f32>> = None;
        for threads in [1usize, 2, 8] {
            let pool = ThreadPool::new(threads);
            let mut a32 = Arena::<f32>::new();
            let mut y32 = vec![0.0f32; d2 * bcols];
            plan32.execute_batch_into(&pool, &mut a32, &x32, bcols, &mut y32);
            match &base32 {
                None => base32 = Some(y32.clone()),
                Some(b) => {
                    for (i, (got, want)) in y32.iter().zip(b).enumerate() {
                        ensure(
                            got.to_bits() == want.to_bits(),
                            format!("{threads} threads changed f32 bits at {i}"),
                        )?;
                    }
                }
            }
            // Per-column relative l2 error against the f64 master stays
            // within the declared (headroom-padded) bound.
            for j in 0..bcols {
                let (mut err2, mut ref2) = (0.0f64, 0.0f64);
                for i in 0..d2 {
                    let w = y64[i * bcols + j];
                    let d = y32[i * bcols + j] as f64 - w;
                    err2 += d * d;
                    ref2 += w * w;
                }
                if ref2 > 0.0 {
                    ensure(
                        (err2 / ref2).sqrt() <= bound.declared_rel_err,
                        format!(
                            "col {j} rel err {:.3e} > declared {:.3e}",
                            (err2 / ref2).sqrt(),
                            bound.declared_rel_err
                        ),
                    )?;
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_wire_dtype_roundtrips_including_v1_frames() {
    use faust::coordinator::QosClass;
    use faust::server::wire::{self, Dtype, WireRequest, WireResponse};
    check("wire dtype roundtrip", &cfg(100), |rng| {
        let rows = rng.below(17);
        let cols = rng.below(5);
        let data = rng.gauss_vec(rows * cols);
        let version = 1 + rng.below(2) as u8;
        // v1 frames cannot carry f32 — the encoder asserts that — so the
        // dtype draw is conditioned on the negotiated version.
        let dtype = if version >= 2 && rng.uniform() < 0.5 { Dtype::F32 } else { Dtype::F64 };
        let req = WireRequest {
            req_id: rng.below(1 << 30) as u64,
            op: "dtype_prop".to_string(),
            class: QosClass::from_u8(rng.below(3) as u8).unwrap(),
            deadline_us: rng.below(1 << 16) as u32,
            dtype,
            version,
            rows,
            cols,
            data: data.clone(),
        };
        let frame = wire::encode_request(&req);
        // Payload bytes follow the dtype: f32 halves them (frame = 4-byte
        // length prefix + header + name + payload).
        let header = if version == 1 { 26 } else { 27 };
        ensure(
            frame.len() == 4 + header + req.op.len() + dtype.elem_bytes() * rows * cols,
            format!("unexpected frame len {}", frame.len()),
        )?;
        let back = wire::decode_request(&frame[4..]).map_err(|e| format!("decode: {e}"))?;
        ensure(back.version == version, "version mismatch")?;
        ensure(back.dtype == dtype, "dtype mismatch")?;
        for (i, (got, want)) in back.data.iter().zip(&data).enumerate() {
            // f64 travels exactly; f32 round-trips as quantize-then-widen.
            let expect = match dtype {
                Dtype::F64 => *want,
                Dtype::F32 => *want as f32 as f64,
            };
            ensure(
                got.to_bits() == expect.to_bits(),
                format!("payload byte-exactness broken at {i}"),
            )?;
        }

        // Responses: encoded at the request's version; v1 forces f64 even
        // when an f32 tier served the job, so the dtype draw here is
        // independent of the request's.
        let resp_dtype = if rng.uniform() < 0.5 { Dtype::F32 } else { Dtype::F64 };
        let resp = WireResponse::Ok {
            req_id: req.req_id,
            epoch: rng.below(1 << 10) as u64,
            rows,
            cols,
            dtype: resp_dtype,
            data: data.clone(),
        };
        let rframe = wire::encode_response(&resp, version);
        let rback = wire::decode_response(&rframe[4..]).map_err(|e| format!("decode resp: {e}"))?;
        match rback {
            WireResponse::Ok { dtype: got_dtype, data: got_data, .. } => {
                let want_dtype = if version == 1 { Dtype::F64 } else { resp_dtype };
                ensure(got_dtype == want_dtype, "response dtype mismatch")?;
                for (i, (got, want)) in got_data.iter().zip(&data).enumerate() {
                    let expect = match want_dtype {
                        Dtype::F64 => *want,
                        Dtype::F32 => *want as f32 as f64,
                    };
                    ensure(
                        got.to_bits() == expect.to_bits(),
                        format!("response payload mismatch at {i}"),
                    )?;
                }
            }
            _ => return Err("Ok response decoded as Err".into()),
        }
        Ok(())
    });
}
