//! ISSUE 6 integration: the TCP ingress soak, scaled for the test suite
//! (the full 100k-request run is `cargo bench --bench serve_latency`).
//!
//! Three open-loop Poisson streams — one per QoS class — drive loopback
//! TCP through the full `wire → admission → batcher → registry →
//! engine` path while the live operator is epoch-swapped between its
//! dense and FAμST backends mid-traffic. Every OK payload is verified
//! against the dense reference, so the assertions below are the
//! subsystem's contract: zero misrouted responses, zero protocol
//! errors, sheds only as the typed `Overloaded` code, and the swap
//! visible as multiple epochs in the responses.

use faust::bench_util::{open_loop_load, ClassLoadReport, OpenLoopConfig};
use faust::coordinator::{
    AdaptiveBatchConfig, BatchOp, Coordinator, CoordinatorConfig, QosClass,
};
use faust::server::wire::Dtype;
use faust::server::{AdmissionConfig, Server, ServerConfig};
use faust::transforms::{hadamard, hadamard_faust};
use std::sync::Arc;
use std::time::Duration;

fn start_service(n: usize, admission: AdmissionConfig) -> (Coordinator, Server) {
    let coord = Coordinator::start(
        vec![("h".to_string(), Arc::new(hadamard(n)) as Arc<dyn BatchOp>)],
        CoordinatorConfig {
            max_batch: 32,
            batch_timeout: Duration::from_micros(200),
            n_workers: 2,
            queue_capacity: 8192,
            adaptive: Some(AdaptiveBatchConfig::default()),
            ..CoordinatorConfig::default()
        },
    );
    let server = Server::start(
        coord.client(),
        ServerConfig { admission, ..ServerConfig::default() },
    )
    .expect("bind loopback");
    (coord, server)
}

#[test]
fn open_loop_soak_across_classes_with_mid_traffic_swaps() {
    let n = 32;
    let (coord, server) = start_service(n, AdmissionConfig::default());
    let addr = server.local_addr().to_string();
    let dense = hadamard(n);
    let requests_per_class = 1200usize;
    let rate = 2400.0; // per class ⇒ ~0.5 s of traffic each

    // Swap the live operator dense → FAμST → dense while traffic flows.
    let registry = coord.registry();
    let swapper = std::thread::spawn(move || {
        let mut swapped = 0usize;
        for k in 0..2 {
            std::thread::sleep(Duration::from_millis(150));
            let op: Arc<dyn BatchOp> = if k % 2 == 0 {
                Arc::new(hadamard_faust(n))
            } else {
                Arc::new(hadamard(n))
            };
            if registry.swap_epoch("h", op).is_ok() {
                swapped += 1;
            }
        }
        swapped
    });

    let mut handles = Vec::new();
    for (k, class) in QosClass::ALL.iter().enumerate() {
        // One of the three streams rides the f32 wire tier (v2 dtype
        // byte): inputs and results quantize in transit, so its
        // verification tolerance carries quantization headroom while the
        // f64 streams keep the strict budget.
        let dtype = if k == 2 { Dtype::F32 } else { Dtype::F64 };
        let cfg = OpenLoopConfig {
            addr: addr.clone(),
            op: "h".to_string(),
            class: *class,
            rate_hz: rate,
            requests: requests_per_class,
            dim: n,
            seed: 0xD00D + k as u64,
            dtype,
            verify_tol: if dtype == Dtype::F32 { 1e-4 } else { 1e-6 },
        };
        let verify = dense.clone();
        handles.push(std::thread::spawn(move || open_loop_load(&cfg, Some(&verify))));
    }
    let reports: Vec<ClassLoadReport> = handles
        .into_iter()
        .map(|h| h.join().expect("stream thread").expect("stream ran"))
        .collect();
    let swapped = swapper.join().expect("swap thread");
    assert_eq!(swapped, 2, "both mid-traffic swaps published");
    server.shutdown();
    let snap = coord.shutdown();

    let mut epochs = std::collections::BTreeSet::new();
    for r in &reports {
        assert_eq!(r.sent, requests_per_class, "{}: open loop sent everything", r.class);
        assert_eq!(r.misrouted, 0, "{}: misrouted/corrupted responses", r.class);
        assert_eq!(r.protocol_errors, 0, "{}: protocol errors", r.class);
        assert_eq!(r.other_errors, 0, "{}: unexpected typed errors", r.class);
        // Every request was answered: verified-OK or typed shed.
        assert_eq!(r.ok + r.shed, r.sent, "{}: request went unanswered", r.class);
        epochs.extend(r.epochs.iter().copied());
    }
    // Initial registration + 2 swaps, all visible in served responses.
    assert!(
        epochs.len() >= 2,
        "mid-traffic swaps never surfaced in responses: {epochs:?}"
    );
    assert_eq!(snap.swaps, 2);
    assert!(snap.ingress_accepted > 0);
    assert_eq!(snap.ingress_active_connections, 0, "connections drained");
}

#[test]
fn overload_sheds_typed_and_loses_nothing() {
    let n = 16;
    // A deliberately tiny admission budget: most of the burst must shed.
    let (coord, server) = start_service(
        n,
        AdmissionConfig { max_inflight: 2, ..AdmissionConfig::default() },
    );
    let addr = server.local_addr().to_string();
    let dense = hadamard(n);
    let cfg = OpenLoopConfig {
        addr,
        op: "h".to_string(),
        class: QosClass::Standard,
        rate_hz: 50_000.0, // far beyond the 2-deep admission budget
        requests: 2000,
        dim: n,
        seed: 99,
        dtype: Dtype::F64,
        verify_tol: 1e-6,
    };
    let r = open_loop_load(&cfg, Some(&dense)).expect("stream ran");
    server.shutdown();
    let snap = coord.shutdown();
    assert_eq!(r.sent, 2000);
    assert_eq!(r.misrouted, 0);
    assert_eq!(r.protocol_errors, 0);
    assert_eq!(r.other_errors, 0, "sheds must be the typed Overloaded code");
    assert_eq!(r.ok + r.shed, r.sent, "every request answered even under overload");
    assert!(r.shed > 0, "this load must actually shed");
    assert_eq!(
        snap.ingress_shed[QosClass::Standard.index()],
        r.shed as u64,
        "per-class shed counter matches the client's view"
    );
}
