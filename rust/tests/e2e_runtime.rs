//! PJRT runtime end-to-end: AOT artifacts (L1 Pallas kernel lowered
//! through the L2 JAX model) execute on the rust PJRT client and match
//! the rust-native implementations.
//!
//! Skips gracefully (with a message) when `artifacts/` has not been built
//! — run `make artifacts` first for full coverage. The whole file is
//! compiled out without the `pjrt` feature (the default offline build).
#![cfg(feature = "pjrt")]

use faust::rng::Rng;
use faust::runtime::Engine;
use faust::transforms::hadamard_faust;

fn engine_or_skip() -> Option<Engine> {
    let eng = match Engine::cpu("artifacts") {
        Ok(e) => e,
        Err(e) => {
            eprintln!("skipping e2e_runtime: no PJRT client: {e}");
            return None;
        }
    };
    if !eng.available("faust_apply_had32") {
        eprintln!("skipping e2e_runtime: artifacts missing (run `make artifacts`)");
        return None;
    }
    Some(eng)
}

#[test]
fn pjrt_faust_apply_matches_native() {
    let Some(mut eng) = engine_or_skip() else { return };
    eng.load("faust_apply_had32").expect("compile artifact");
    let n = 32;
    let b = 8;
    let hf = hadamard_faust(n);
    let facs: Vec<Vec<f32>> = hf
        .factors()
        .iter()
        .map(|f| f.to_dense().data().iter().map(|&v| v as f32).collect())
        .collect();
    let mut rng = Rng::new(77);
    let cols: Vec<Vec<f64>> = (0..b).map(|_| rng.gauss_vec(n)).collect();
    let mut x = vec![0f32; n * b];
    for (c, col) in cols.iter().enumerate() {
        for i in 0..n {
            x[i * b + c] = col[i] as f32;
        }
    }
    let xdims = [n, b];
    let fdims = [n, n];
    let mut inputs: Vec<(&[f32], &[usize])> = vec![(&x, &xdims[..])];
    for f in &facs {
        inputs.push((f, &fdims[..]));
    }
    let out = eng.run_f32("faust_apply_had32", &inputs).expect("execute");
    assert_eq!(out[0].1, vec![n, b]);
    for (c, col) in cols.iter().enumerate() {
        let y = hf.apply(col);
        for i in 0..n {
            let d = (out[0].0[i * b + c] as f64 - y[i]).abs();
            assert!(d < 1e-4, "mismatch at ({i},{c}): {d}");
        }
    }
}

#[test]
fn pjrt_palm_step_descends_like_native() {
    // Run the AOT palm4MSA iteration on the Hadamard-32 split and verify
    // the objective decreases across PJRT-executed iterations.
    let Some(mut eng) = engine_or_skip() else { return };
    if !eng.available("palm_grad_step") {
        eprintln!("skipping: palm_grad_step artifact missing");
        return;
    }
    eng.load("palm_grad_step").expect("compile artifact");
    let n = 32usize;
    let h = faust::transforms::hadamard(n);
    let a: Vec<f32> = h.data().iter().map(|&v| v as f32).collect();
    // Toolbox split init: S = Id, T = 0, lam = 1.
    let mut s: Vec<f32> = faust::linalg::Mat::eye(n, n)
        .data()
        .iter()
        .map(|&v| v as f32)
        .collect();
    let mut t = vec![0f32; n * n];
    let mut lam = 1f32;
    let dims = [n, n];
    let scalar_dims: [usize; 0] = [];
    let objective = |s: &[f32], t: &[f32], lam: f32| -> f64 {
        // ½‖A − λ·T·S‖²  (row-major f32 buffers).
        let mut acc = 0.0f64;
        for i in 0..n {
            for j in 0..n {
                let mut ts = 0.0f64;
                for k in 0..n {
                    ts += t[i * n + k] as f64 * s[k * n + j] as f64;
                }
                let d = h.at(i, j) - lam as f64 * ts;
                acc += d * d;
            }
        }
        0.5 * acc
    };
    let mut objs = vec![objective(&s, &t, lam)];
    for _ in 0..6 {
        let lam_arr = [lam];
        let inputs: Vec<(&[f32], &[usize])> = vec![
            (&a, &dims[..]),
            (&s, &dims[..]),
            (&t, &dims[..]),
            (&lam_arr, &scalar_dims[..]),
        ];
        let out = eng.run_f32("palm_grad_step", &inputs).expect("execute");
        s = out[0].0.clone();
        t = out[1].0.clone();
        lam = out[2].0[0];
        objs.push(objective(&s, &t, lam));
    }
    // Overall descent to (near-)exactness. Strict per-iteration
    // monotonicity is not asserted: the L2 graph estimates the Lipschitz
    // step with a fixed-iteration power method, which can transiently
    // under-estimate ‖L‖₂ and produce a small wiggle — the native rust
    // path (adaptive power iteration) is the monotone reference.
    assert!(
        *objs.last().unwrap() < 1e-4 * objs[0],
        "PJRT palm iterations did not converge: {objs:?}"
    );
    let mid = objs[objs.len() / 2];
    assert!(mid < objs[0], "no early progress: {objs:?}");
}
