//! ISSUE 8 integration: crash/recovery over the durable operator store.
//!
//! Phase 1 (cold) pays the full factorization price: PALM learns a
//! FAμST, a sharded coordinator serves it over loopback TCP with a
//! store directory, and a snapshot is taken **mid-traffic**. The server
//! is then dropped — simulating a crash/restart boundary — and phase 2
//! (warm) stands a fresh service up purely from the store. The
//! contract asserted here:
//!
//! - the warm server answers the *same* payload bits for the same input
//!   (factors survived persist → load bitwise);
//! - responses carry an epoch at or past the snapshot generation;
//! - **zero re-factorization**: the process-wide PALM iteration counter
//!   does not move at all during the warm phase — restart cost is plan
//!   compilation, not learning.

use faust::coordinator::{BatchOp, Coordinator, CoordinatorConfig, QosClass};
use faust::engine::ApplyEngine;
use faust::hierarchical::{factorize, HierarchicalConfig};
use faust::palm::iterations_total;
use faust::server::wire::WireResponse;
use faust::server::{ServeConn, Server, ServerConfig};
use faust::transforms::{hadamard, hadamard_faust};
use std::path::PathBuf;
use std::sync::Arc;

fn store_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("faust_recovery_{}_{tag}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    d
}

fn ok_payload(resp: WireResponse) -> (u64, Vec<f64>) {
    match resp {
        WireResponse::Ok { epoch, data, .. } => (epoch, data),
        other => panic!("expected OK response, got {other:?}"),
    }
}

#[test]
fn warm_restart_from_store_serves_identical_bits_without_palm() {
    let n = 16;
    let dir = store_dir("warm");
    let h = hadamard(n);
    let x: Vec<f64> = (0..n).map(|i| (i as f64 * 0.7).sin()).collect();

    // ---- Phase 1: cold start — learn, serve, snapshot mid-traffic. ----
    let iters_before_cold = iterations_total();
    let learned = factorize(&h, &HierarchicalConfig::hadamard(n));
    assert!(learned.relative_error_fro(&h) < 1e-6);
    assert!(iterations_total() > iters_before_cold, "cold start must actually run PALM");

    let engine = ApplyEngine::with_threads(2);
    let coord = Coordinator::start(
        vec![("h".to_string(), Arc::new(engine.op(&learned)) as Arc<dyn BatchOp>)],
        CoordinatorConfig { n_shards: 2, ..CoordinatorConfig::default() },
    );
    let server = Server::start(
        coord.client(),
        ServerConfig { store_dir: Some(dir.clone()), ..ServerConfig::default() },
    )
    .expect("bind loopback");
    let addr = server.local_addr().to_string();

    let mut conn = ServeConn::connect(&addr).unwrap();
    // Pipeline traffic and snapshot while requests are in flight.
    for _ in 0..4 {
        conn.send("h", QosClass::Standard, 0, n, 1, x.clone()).unwrap();
    }
    let report = coord.registry().persist_all(&dir).expect("mid-traffic snapshot");
    assert_eq!(report.persisted, vec!["h".to_string()]);
    let mut cold_epoch = 0;
    let mut cold_data = Vec::new();
    for _ in 0..4 {
        let (epoch, data) = ok_payload(conn.recv().unwrap());
        cold_epoch = epoch;
        cold_data = data;
    }
    let want = h.matvec(&x);
    for i in 0..n {
        assert!((cold_data[i] - want[i]).abs() < 1e-6, "cold response wrong");
    }

    // Drop the server (crash/restart boundary). Its shutdown also
    // re-snapshots — both writes are atomic under the same names.
    drop(conn);
    server.shutdown();
    coord.shutdown();

    // ---- Phase 2: warm start — restore from the store alone. ----
    let iters_before_warm = iterations_total();
    let engine2 = ApplyEngine::with_threads(2);
    let coord2 = Coordinator::start(
        vec![],
        CoordinatorConfig { n_shards: 2, ..CoordinatorConfig::default() },
    );
    let restore = coord2
        .registry()
        .load_store(&dir, |_, f| Arc::new(engine2.op(f)) as Arc<dyn BatchOp>)
        .expect("store readable");
    assert_eq!(restore.loaded, vec!["h".to_string()]);
    assert!(restore.corrupt.is_empty(), "no corruption was injected");
    let server2 = Server::start(coord2.client(), ServerConfig::default()).expect("rebind");

    let mut conn2 = ServeConn::connect(&server2.local_addr().to_string()).unwrap();
    let (warm_epoch, warm_data) =
        ok_payload(conn2.apply("h", QosClass::Standard, x.clone()).unwrap());
    // Same input, same factors ⇒ same bits (f64 wire frames are exact).
    assert_eq!(warm_data.len(), cold_data.len());
    for i in 0..n {
        assert_eq!(
            warm_data[i].to_bits(),
            cold_data[i].to_bits(),
            "warm restart changed served bits at row {i}"
        );
    }
    // The restored generation publishes at or past the snapshot epoch.
    assert!(
        warm_epoch >= cold_epoch,
        "warm epoch {warm_epoch} regressed below snapshot epoch {cold_epoch}"
    );
    drop(conn2);
    server2.shutdown();
    let snap = coord2.shutdown();
    assert_eq!(snap.store_loaded, 1);
    // The zero-re-factorization witness: not one PALM iteration ran
    // during the entire warm phase.
    assert_eq!(iterations_total(), iters_before_warm, "warm restart re-ran PALM");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn warm_restart_skips_a_torn_snapshot_and_still_serves_the_rest() {
    // Crash during a snapshot write: one file is torn. The warm server
    // must come up with every intact operator, report the torn file as
    // a typed skip, and never panic or serve garbage.
    let n = 8;
    let dir = store_dir("torn");
    let engine = ApplyEngine::with_threads(1);
    // The exact butterfly, not a learned operator: this test must not
    // touch PALM, so it can't perturb the other test's process-global
    // zero-iteration witness when the harness runs them in parallel.
    let butterfly = hadamard_faust(n);
    let registry = faust::coordinator::Registry::new(None);
    registry
        .register("keep", Arc::new(engine.op(&butterfly)) as Arc<dyn BatchOp>)
        .unwrap();
    registry.persist_all(&dir).unwrap();
    // Fabricate the torn neighbor from the good file's first half.
    let good = std::fs::read(faust::store::op_path(&dir, "keep")).unwrap();
    std::fs::write(dir.join("torn.fstore"), &good[..good.len() / 2]).unwrap();

    let coord = Coordinator::start(vec![], CoordinatorConfig::default());
    let restore = coord
        .registry()
        .load_store(&dir, |_, f| Arc::new(engine.op(f)) as Arc<dyn BatchOp>)
        .expect("directory itself is readable");
    assert_eq!(restore.loaded, vec!["keep".to_string()]);
    assert_eq!(restore.corrupt.len(), 1, "torn file must surface, typed");
    let server = Server::start(coord.client(), ServerConfig::default()).unwrap();
    let mut conn = ServeConn::connect(&server.local_addr().to_string()).unwrap();
    let x = vec![1.0; n];
    let (_, data) = match conn.apply("keep", QosClass::Standard, x.clone()).unwrap() {
        WireResponse::Ok { epoch, data, .. } => (epoch, data),
        other => panic!("intact operator must serve: {other:?}"),
    };
    let want = hadamard(n).matvec(&x);
    for i in 0..n {
        assert!((data[i] - want[i]).abs() < 1e-6);
    }
    drop(conn);
    server.shutdown();
    let snap = coord.shutdown();
    assert_eq!((snap.store_loaded, snap.store_skipped), (1, 1));
    std::fs::remove_dir_all(&dir).ok();
}
