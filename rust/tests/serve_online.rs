//! ISSUE 9 integration: `serve --online-learn` under the loopback soak.
//!
//! An open-loop Poisson stream drives loopback TCP through the full
//! `wire → admission → batcher → registry → engine` path while an
//! online learner — fed the served operator's columns from a parallel
//! observation stream — epoch-swaps improved generations into the live
//! registry. The contract under test: across ≥ 3 online swaps, zero
//! requests are misrouted, zero protocol errors, and every request is
//! answered (verified-OK or typed shed). Payloads are not checked
//! against the dense reference here: the learner's early generations
//! are *approximations* by design, and which generation a request hits
//! depends on arrival timing — routing integrity, not approximation
//! error, is this test's subject (the error trajectory is gated by
//! `cargo bench --bench online_drift`).

use faust::bench_util::{open_loop_load, OpenLoopConfig};
use faust::coordinator::{
    BatchOp, Coordinator, CoordinatorConfig, OnlineLearnerTask, QosClass,
};
use faust::engine::ExecCtx;
use faust::faust::Faust;
use faust::palm::online::{OnlineConfig, OnlinePalm};
use faust::palm::PalmConfig;
use faust::prox::Constraint;
use faust::server::wire::Dtype;
use faust::server::{Server, ServerConfig};
use faust::transforms::hadamard;
use std::sync::Arc;

#[test]
fn online_swaps_misroute_nothing_under_loopback_soak() {
    let n = 16;
    let dense = hadamard(n);
    let coord = Coordinator::start(
        vec![("h".to_string(), Arc::new(dense.clone()) as Arc<dyn BatchOp>)],
        CoordinatorConfig::online_learning(),
    );
    let server = Server::start(coord.client(), ServerConfig::default()).expect("bind loopback");
    let addr = server.local_addr().to_string();

    // Cold learner: every early sweep improves, so the default cadence
    // (swap_every = 4 mini-batches of 8 columns) publishes repeatedly
    // while the load below is in flight.
    let learner = coord
        .online_learner(
            "h",
            OnlinePalm::cold(
                &[(n, n); 4],
                OnlineConfig::new(PalmConfig::new(vec![Constraint::SpRowCol(2); 4], 1)),
            ),
        )
        .expect("online learning is on");
    let task = OnlineLearnerTask::spawn(
        learner,
        ExecCtx::new(1),
        |f: &Faust| Arc::new(f.clone()) as Arc<dyn BatchOp>,
        256,
    );

    // The request stream and the observation stream run concurrently.
    let cfg = OpenLoopConfig {
        addr,
        op: "h".to_string(),
        class: QosClass::Standard,
        rate_hz: 3000.0,
        requests: 1500,
        dim: n,
        seed: 0x0911,
        dtype: Dtype::F64,
        verify_tol: 1e-6, // unused: payload verification is off (None)
    };
    let load = std::thread::spawn(move || open_loop_load(&cfg, None));
    for _ in 0..60 {
        for j in 0..n {
            assert!(task.observe(j, dense.col(j)), "learner died mid-stream");
        }
    }
    let rep = task.finish();
    let r = load.join().expect("load thread").expect("stream ran");
    server.shutdown();
    let snap = coord.shutdown();

    assert!(
        rep.swaps >= 3,
        "needed ≥3 online swaps under traffic, got {} ({} batches, rel err {:.2e})",
        rep.swaps,
        rep.batches,
        rep.rel_err
    );
    assert_eq!(r.sent, 1500, "open loop sent everything");
    assert_eq!(r.misrouted, 0, "misrouted/corrupted responses across online swaps");
    assert_eq!(r.protocol_errors, 0, "protocol errors across online swaps");
    assert_eq!(r.other_errors, 0, "unexpected typed errors");
    assert_eq!(r.ok + r.shed, r.sent, "every request answered");
    // The learner's swaps are the registry's swaps, and the drift
    // metrics surfaced in the final snapshot.
    assert_eq!(snap.swaps, rep.swaps, "all swaps came from the online learner");
    assert_eq!(snap.online_swaps, rep.swaps);
    assert_eq!(snap.online_cols, rep.cols);
    assert_eq!(
        snap.online_rel_err.to_bits(),
        rep.rel_err.to_bits(),
        "drift gauge must hold the last sweep's relative error"
    );
    assert_eq!(snap.ingress_active_connections, 0, "connections drained");
}
