//! Cross-module integration tests: factorize → serve → solve pipelines.

use faust::coordinator::{BatchOp, Coordinator, CoordinatorConfig};
use faust::dictlearn::{faust_dictionary_learning, KsvdConfig};
use faust::hierarchical::{factorize, HierarchicalConfig};
use faust::image::{add_noise, denoise, make_image, psnr, random_patches, ImageKind};
use faust::meg::{localization_experiment, meg_model};
use faust::rng::Rng;
use faust::solvers::{fista, iht, omp, LinOp};
use faust::transforms::{hadamard, hadamard_faust};
use std::sync::Arc;
use std::time::Duration;

#[test]
fn factorize_then_solve_inverse_problem() {
    // Full §V pipeline at test scale: synthetic gain → FAμST → OMP
    // localization quality close to the dense matrix.
    let (m, n) = (64, 512);
    let model = meg_model(m, n, 21);
    let cfg = HierarchicalConfig::meg(m, n, 3, 8, 2 * m, 0.8, 1.4 * (m * m) as f64);
    let fst = factorize(&model.gain, &cfg);
    assert!(fst.rcg() > 2.0, "rcg = {}", fst.rcg());

    let dense_stats = localization_experiment(&model, &model.gain, 40, 6.0, 100.0, 5);
    let faust_stats = localization_experiment(&model, &fst, 40, 6.0, 100.0, 5);
    // The FAμST should not be wildly worse than the dense operator.
    assert!(
        faust_stats.median() <= dense_stats.median() + 3.0,
        "faust median {} vs dense {}",
        faust_stats.median(),
        dense_stats.median()
    );
}

#[test]
fn factorize_then_serve_through_coordinator() {
    // Hadamard FAμST behind the coordinator answers exactly like the
    // dense operator applied locally.
    let n = 64;
    let a = hadamard(n);
    let cfg = HierarchicalConfig::hadamard(n);
    let fst = factorize(&a, &cfg);
    assert!(fst.relative_error_fro(&a) < 1e-6);

    let coord = Coordinator::start(
        vec![("h".to_string(), Arc::new(fst) as Arc<dyn BatchOp>)],
        CoordinatorConfig {
            max_batch: 8,
            batch_timeout: Duration::from_micros(100),
            n_workers: 2,
            queue_capacity: 256,
            adaptive: None,
            ..CoordinatorConfig::default()
        },
    );
    let client = coord.client();
    let mut rng = Rng::new(3);
    for _ in 0..32 {
        let x = rng.gauss_vec(n);
        let served = client.apply("h", x.clone()).unwrap();
        let local = a.matvec(&x);
        for i in 0..n {
            assert!((served[i] - local[i]).abs() < 1e-8);
        }
    }
    let snap = coord.shutdown();
    assert_eq!(snap.completed, 32);
}

#[test]
fn all_solvers_work_with_faust_operators() {
    // OMP, IHT and FISTA all accept a FAμST in place of a dense matrix.
    let n = 32;
    let h = hadamard(n);
    let hf = hadamard_faust(n);
    let mut rng = Rng::new(9);
    let mut x0 = vec![0.0; n];
    for i in rng.sample_indices(n, 3) {
        x0[i] = 2.0 + rng.uniform();
    }
    let y = h.matvec(&x0);

    let r_omp = omp(&hf, &y, 3, None);
    assert!(r_omp.residual_norm < 1e-8);

    let r_iht = iht(&hf, &y, 3, 300, 1);
    assert!(r_iht.residual_norm < 1e-6, "iht resid {}", r_iht.residual_norm);

    let r_fista = fista(&hf, &y, 0.01, 300, 2);
    // FISTA is biased by the l1 penalty; check support only.
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&i, &j| r_fista.x[j].abs().partial_cmp(&r_fista.x[i].abs()).unwrap());
    let mut got = idx[..3].to_vec();
    got.sort_unstable();
    let mut want: Vec<usize> = (0..n).filter(|&i| x0[i] != 0.0).collect();
    want.sort_unstable();
    assert_eq!(got, want);
}

#[test]
fn dictionary_learning_to_denoising_pipeline() {
    // §VI end-to-end at test scale: noisy image → patches → FAμST
    // dictionary → denoise → PSNR improves.
    let img = make_image(ImageKind::Smooth, 64, 11);
    let mut rng = Rng::new(12);
    let noisy = add_noise(&img, 25.0, &mut rng);
    let patches = random_patches(&noisy, 8, 400, &mut rng);
    let kcfg = KsvdConfig { n_atoms: 96, sparsity: 4, n_iter: 3, seed: 1 };
    let hcfg = HierarchicalConfig::dictionary(64, 96, 3, 4, 256, 0.5, 4096.0);
    let (fst, _) = faust_dictionary_learning(&patches, &kcfg, &hcfg);
    let den = denoise(&noisy, &fst, 8, 4, 4);
    let before = psnr(&noisy, &img);
    let after = psnr(&den, &img);
    assert!(
        after > before + 1.0,
        "FAuST denoising didn't help: {before:.2} -> {after:.2}"
    );
}

#[test]
fn faust_save_load_preserves_serving_behaviour() {
    let n = 32;
    let fst = hadamard_faust(n);
    let dir = std::env::temp_dir().join("faust_integration");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("had32.faust");
    fst.save(&path).unwrap();
    let loaded = faust::faust::Faust::load(&path).unwrap();
    let mut rng = Rng::new(4);
    let x = rng.gauss_vec(n);
    let y1 = fst.apply(&x);
    let y2 = loaded.apply(&x);
    for i in 0..n {
        assert!((y1[i] - y2[i]).abs() < 1e-12);
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn linop_flop_accounting_consistent_with_rcg() {
    let n = 128;
    let a = hadamard(n);
    let f = hadamard_faust(n);
    let flops_dense = LinOp::flops_per_apply(&a) as f64;
    let flops_faust = LinOp::flops_per_apply(&f) as f64;
    let gain = flops_dense / flops_faust;
    assert!((gain - f.rcg()).abs() < 1e-9, "gain {gain} vs rcg {}", f.rcg());
}

#[test]
fn online_refactorization_hot_swaps_mid_serve() {
    // The PR-3 serving story end to end: clients hammer an operator while
    // the same engine re-learns it (online refactorization) and publishes
    // the fresh generation via Registry::swap_epoch — no failed and no
    // misrouted requests, zero service stall.
    use faust::coordinator::{engine_ops, AdaptiveBatchConfig};
    use faust::engine::ApplyEngine;
    use faust::hierarchical::factorize_with_ctx;
    use std::sync::atomic::{AtomicBool, Ordering};

    let n = 32;
    let h = hadamard(n);
    let engine = Arc::new(ApplyEngine::with_threads(2));
    let ops = engine_ops(&engine, vec![("gain".to_string(), hadamard_faust(n))], 8);
    let cfg = CoordinatorConfig {
        adaptive: Some(AdaptiveBatchConfig::default()),
        ..CoordinatorConfig::default()
    };
    let coord = Coordinator::start(ops, cfg);
    let client = coord.client();
    let registry = coord.registry();

    // Clients hammer the operator for the whole duration.
    let stop = Arc::new(AtomicBool::new(false));
    let mut clients = vec![];
    for t in 0..2u64 {
        let c = client.clone();
        let h = h.clone();
        let stop = stop.clone();
        clients.push(std::thread::spawn(move || {
            let mut rng = Rng::new(40 + t);
            let mut served = 0u64;
            while !stop.load(Ordering::Acquire) {
                let x = rng.gauss_vec(n);
                let y = c.apply("gain", x.clone()).expect("request failed during swap");
                let want = h.matvec(&x);
                for i in 0..n {
                    assert!(
                        (y[i] - want[i]).abs() < 1e-4,
                        "misrouted or garbled response mid-swap"
                    );
                }
                served += 1;
            }
            served
        }));
    }

    // On-line refactorization on the serving engine's own ctx…
    let f = factorize_with_ctx(&engine.ctx(), &h, &HierarchicalConfig::hadamard(n));
    assert!(f.relative_error_fro(&h) < 1e-6);
    // …published into the running service while traffic flows.
    let epoch = registry
        .swap_epoch(
            "gain",
            Arc::new(engine.op_batch_hint(&f, 8)) as Arc<dyn BatchOp>,
        )
        .expect("hot swap failed");
    assert!(epoch >= 2);
    std::thread::sleep(Duration::from_millis(30));
    stop.store(true, Ordering::Release);
    let total: u64 = clients.into_iter().map(|c| c.join().unwrap()).sum();
    assert!(total > 0, "no requests flowed during refactorization");

    // Requests submitted after the swap are served by the new generation.
    let mut rng = Rng::new(77);
    let x = rng.gauss_vec(n);
    let y = client.apply("gain", x.clone()).unwrap();
    let want = h.matvec(&x);
    for i in 0..n {
        assert!((y[i] - want[i]).abs() < 1e-4);
    }
    let snap = coord.shutdown();
    assert_eq!(snap.swaps, 1);
    assert_eq!(snap.rejected, 0, "hot swap caused rejected requests");
    assert_eq!(snap.completed, snap.submitted, "requests were lost in the swap");
}

#[test]
fn fleet_refactorization_hot_swaps_every_operator_mid_serve() {
    // The ISSUE-4 serving story end to end: a fleet of served operators
    // is re-learned *concurrently* on the serving engine's ctx
    // (cross-operator batched PALM sweeps) and each one is epoch-swapped
    // the moment its own factorization finishes — with zero failed
    // requests on any operator throughout.
    use faust::coordinator::engine_ops;
    use faust::engine::{ApplyEngine, FleetCtx};
    use faust::linalg::Mat;
    use std::sync::atomic::{AtomicBool, Ordering};

    let n = 16;
    let n_ops = 3usize;
    let h = hadamard(n);
    let engine = Arc::new(ApplyEngine::with_threads(2));
    let ops = engine_ops(
        &engine,
        (0..n_ops)
            .map(|i| (format!("op{i}"), hadamard_faust(n)))
            .collect(),
        8,
    );
    let coord = Coordinator::start(ops, CoordinatorConfig::default());
    let client = coord.client();
    let registry = coord.registry();

    // Clients hammer every fleet operator for the whole duration.
    let stop = Arc::new(AtomicBool::new(false));
    let mut clients = vec![];
    for t in 0..2u64 {
        let c = client.clone();
        let h = h.clone();
        let stop = stop.clone();
        clients.push(std::thread::spawn(move || {
            let mut rng = Rng::new(60 + t);
            let mut served = 0u64;
            while !stop.load(Ordering::Acquire) {
                let op = format!("op{}", rng.below(n_ops));
                let x = rng.gauss_vec(n);
                let y = c
                    .apply(&op, x.clone())
                    .expect("request failed during fleet refactorization");
                let want = h.matvec(&x);
                for i in 0..n {
                    assert!(
                        (y[i] - want[i]).abs() < 1e-4,
                        "misrouted or garbled response mid-fleet-swap"
                    );
                }
                served += 1;
            }
            served
        }));
    }

    // Refactorize the whole fleet on the serving engine's own ctx; each
    // operator is swapped in as soon as its factorization completes.
    let initial_epoch = registry.epoch();
    let fleet = FleetCtx::new(engine.ctx());
    let cfgs: Vec<HierarchicalConfig> = (0..n_ops)
        .map(|i| {
            let mut c = HierarchicalConfig::hadamard(n);
            c.seed ^= i as u64;
            c
        })
        .collect();
    let jobs: Vec<(String, &Mat, &HierarchicalConfig)> = cfgs
        .iter()
        .enumerate()
        .map(|(i, c)| (format!("op{i}"), &h, c))
        .collect();
    let outcomes = registry.refactorize_fleet(&fleet, &jobs, |_, f| {
        Arc::new(engine.op_batch_hint(f, 8)) as Arc<dyn BatchOp>
    });
    for o in &outcomes {
        let epoch = *o.outcome.as_ref().expect("fleet swap failed");
        assert!(epoch > initial_epoch, "'{}' not republished", o.name);
        assert!(o.rel_err < 1e-6, "'{}' learned a bad operator", o.name);
    }

    std::thread::sleep(Duration::from_millis(30));
    stop.store(true, Ordering::Release);
    let total: u64 = clients.into_iter().map(|c| c.join().unwrap()).sum();
    assert!(total > 0, "no requests flowed during fleet refactorization");

    // Requests submitted after the fleet swap are served by the learned
    // generations.
    let mut rng = Rng::new(88);
    for i in 0..n_ops {
        let x = rng.gauss_vec(n);
        let y = client.apply(&format!("op{i}"), x.clone()).unwrap();
        let want = h.matvec(&x);
        for k in 0..n {
            assert!((y[k] - want[k]).abs() < 1e-4);
        }
    }
    let snap = coord.shutdown();
    assert_eq!(snap.swaps, n_ops as u64, "every fleet member must swap");
    assert_eq!(snap.rejected, 0, "fleet swap caused rejected requests");
    assert_eq!(
        snap.completed, snap.submitted,
        "requests were lost during the fleet swap"
    );
}

#[test]
fn adaptive_batching_matches_fixed_results_exactly() {
    // Same operator, same requests — adaptive sizing may batch
    // differently but must return bit-identical answers.
    use faust::coordinator::AdaptiveBatchConfig;

    let n = 64;
    let h = hadamard(n);
    let run = |adaptive: Option<AdaptiveBatchConfig>| -> Vec<Vec<f64>> {
        let coord = Coordinator::start(
            vec![("h".to_string(), Arc::new(h.clone()) as Arc<dyn BatchOp>)],
            CoordinatorConfig { adaptive, ..CoordinatorConfig::default() },
        );
        let client = coord.client();
        let mut rng = Rng::new(55);
        let out: Vec<Vec<f64>> = (0..40)
            .map(|_| client.apply("h", rng.gauss_vec(n)).unwrap())
            .collect();
        coord.shutdown();
        out
    };
    let fixed = run(None);
    let adaptive = run(Some(AdaptiveBatchConfig::default()));
    for (a, b) in fixed.iter().zip(&adaptive) {
        for i in 0..n {
            assert_eq!(a[i], b[i], "adaptive batching changed a result bit");
        }
    }
}
