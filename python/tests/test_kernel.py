"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes and seeds; assert_allclose against ref.py is THE
core correctness signal for the compile path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.palm_grad import faust_apply, palm_grad_step
from compile.kernels.ref import faust_apply_ref, palm_grad_step_ref, proj_sp_ref

jax.config.update("jax_enable_x64", False)


def _rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), dtype=jnp.float32)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(2, 24),
    n=st.integers(2, 24),
    p=st.integers(2, 24),
    q=st.integers(2, 24),
    seed=st.integers(0, 2**31 - 1),
)
def test_palm_grad_step_matches_ref(m, n, p, q, seed):
    rng = np.random.default_rng(seed)
    a = _rand(rng, m, n)
    l = _rand(rng, m, p)
    s = _rand(rng, p, q)
    r = _rand(rng, q, n)
    lam = jnp.float32(rng.uniform(0.1, 3.0))
    c = jnp.float32(rng.uniform(0.5, 10.0))
    got = palm_grad_step(a, l, s, r, lam, c)
    want = palm_grad_step_ref(a, l, s, r, lam, c)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(
    block=st.sampled_from([8, 16, 32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_palm_grad_step_block_invariance(block, seed):
    """The tile size must not change the numerics."""
    rng = np.random.default_rng(seed)
    a, l, s, r = (_rand(rng, 12, 20), _rand(rng, 12, 16), _rand(rng, 16, 20), jnp.eye(20))
    lam, c = jnp.float32(1.3), jnp.float32(2.0)
    got = palm_grad_step(a, l, s, r, lam, c, block=block)
    want = palm_grad_step_ref(a, l, s, r, lam, c)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(2, 16),
    b=st.integers(1, 8),
    j=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_faust_apply_matches_ref(n, b, j, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, n, b)
    factors = []
    dim = n
    for _ in range(j):
        nxt = int(rng.integers(2, 16))
        factors.append(_rand(rng, nxt, dim))
        dim = nxt
    lam = jnp.float32(rng.uniform(0.2, 2.0))
    got = faust_apply(x, factors, lam)
    want = faust_apply_ref(x, factors, lam)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_grad_step_identity_sides_is_plain_residual_descent():
    """With L = R = Id and lam = c = 1: S' = S - (S - A) = A."""
    rng = np.random.default_rng(0)
    a = _rand(rng, 8, 8)
    s = _rand(rng, 8, 8)
    eye = jnp.eye(8)
    got = palm_grad_step(a, eye, s, eye, jnp.float32(1.0), jnp.float32(1.0))
    np.testing.assert_allclose(np.asarray(got), np.asarray(a), rtol=1e-5, atol=1e-5)


def test_proj_sp_ref_properties():
    rng = np.random.default_rng(1)
    u = _rand(rng, 6, 7)
    p = proj_sp_ref(u, 5)
    assert int((np.asarray(p) != 0).sum()) <= 5
    np.testing.assert_allclose(float(jnp.linalg.norm(p)), 1.0, rtol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32])
def test_dtype_is_preserved(dtype):
    rng = np.random.default_rng(2)
    a = jnp.asarray(rng.standard_normal((6, 6)), dtype=dtype)
    s = jnp.asarray(rng.standard_normal((6, 6)), dtype=dtype)
    eye = jnp.eye(6, dtype=dtype)
    out = palm_grad_step(a, eye, s, eye, jnp.asarray(1.0, dtype), jnp.asarray(1.0, dtype))
    assert out.dtype == dtype
