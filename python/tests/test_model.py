"""L2 correctness: the palm4MSA iteration graph behaves like the algorithm.

Checks the descent property, constraint feasibility after projection, and
that the fixed-shape AOT entry points lower to HLO text cleanly.
"""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model
from compile.aot import build_artifacts, to_hlo_text


def _hadamard(n):
    h = np.array([[1.0]])
    while h.shape[0] < n:
        h = np.block([[h, h], [h, -h]])
    return jnp.asarray(h / np.sqrt(n), dtype=jnp.float32)


def _objective(a, s, t, lam):
    return 0.5 * float(jnp.sum((a - lam * (t @ s)) ** 2))


def test_palm_iteration_descends_on_hadamard():
    n = 32
    a = _hadamard(n)
    # Toolbox split init: sparse factor = Id, residual = 0, lam = 1.
    s = jnp.eye(n, dtype=jnp.float32)
    t = jnp.zeros((n, n), dtype=jnp.float32)
    lam = jnp.float32(1.0)
    objs = []
    for _ in range(15):
        s, t, lam = model.palm4msa_iteration_had32(a, s, t, lam)
        objs.append(_objective(a, s, t, float(lam)))
    # Overall descent to (near-)exactness. Strict per-iteration
    # monotonicity is not asserted: the L2 graph uses a fixed-iteration
    # power method for the Lipschitz step, which can transiently
    # under-estimate ||L||_2 and produce a small wiggle early on; the
    # rust-native path (adaptive power iteration) is the monotone
    # reference. See rust/tests/e2e_runtime.rs for the same check via PJRT.
    assert objs[-1] < 1e-4 * objs[0], objs
    assert objs[len(objs) // 2] < objs[0], objs
    # The tail, once converged, must be non-increasing.
    for before, after in zip(objs[8:], objs[9:]):
        assert after <= before * (1 + 1e-3) + 1e-8, (before, after)


def test_palm_iteration_respects_sparsity():
    n = 32
    a = _hadamard(n)
    s = jnp.eye(n, dtype=jnp.float32)
    t = jnp.zeros((n, n), dtype=jnp.float32)
    lam = jnp.float32(1.0)
    for _ in range(3):
        s, t, lam = model.palm4msa_iteration_had32(a, s, t, lam)
    # splincol(2): union of 2-per-row and 2-per-column supports.
    assert int((np.asarray(s) != 0).sum()) <= 2 * (n + n)
    assert int((np.asarray(t) != 0).sum()) <= (n // 2) * (n + n)
    np.testing.assert_allclose(float(jnp.linalg.norm(s)), 1.0, rtol=1e-5)


def test_proj_sp_matches_ref_shape_and_norm():
    rng = np.random.default_rng(3)
    u = jnp.asarray(rng.standard_normal((10, 10)), dtype=jnp.float32)
    p = model.proj_sp(u, 17)
    assert int((np.asarray(p) != 0).sum()) <= 17
    np.testing.assert_allclose(float(jnp.linalg.norm(p)), 1.0, rtol=1e-6)


def test_artifacts_lower_to_hlo_text():
    for name, lowered in build_artifacts():
        text = to_hlo_text(lowered)
        assert text.startswith("HloModule"), name
        assert len(text) > 200, name


def test_faust_apply_had32_shape():
    n, b = 32, 8
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.standard_normal((n, b)), dtype=jnp.float32)
    fs = [jnp.asarray(rng.standard_normal((n, n)), dtype=jnp.float32) for _ in range(5)]
    y = model.faust_apply_had32(x, *fs)
    assert y.shape == (n, b)
    # Chain-of-matmuls reference.
    want = fs[4] @ fs[3] @ fs[2] @ fs[1] @ fs[0] @ x
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), rtol=2e-3, atol=2e-3)
