"""AOT: lower the L2 entry points to HLO *text* artifacts.

HLO text (NOT lowered.compile()/serialize()) is the interchange format:
jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which the
`xla` crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the
text parser reassigns ids, so text round-trips cleanly. See
/opt/xla-example/README.md.

Usage: python -m compile.aot [--out-dir ../artifacts]
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def build_artifacts():
    """(name, lowered) pairs for every artifact we ship."""
    n = 32
    arts = []
    # One palm4MSA sweep for the Hadamard-32 2-factor split.
    lowered = jax.jit(
        lambda a, s, t, lam: model.palm4msa_iteration_had32(a, s, t, lam)
    ).lower(spec(n, n), spec(n, n), spec(n, n), spec())
    arts.append(("palm_grad_step", lowered))
    # FAuST apply for the 5-factor Hadamard-32 chain, batch of 8 vectors.
    lowered = jax.jit(model.faust_apply_had32).lower(
        spec(n, 8), spec(n, n), spec(n, n), spec(n, n), spec(n, n), spec(n, n)
    )
    arts.append(("faust_apply_had32", lowered))
    # Dense matvec twin (same shapes) for PJRT-side dense-vs-faust parity.
    lowered = jax.jit(lambda m, x: (m @ x,)).lower(spec(n, n), spec(n, 8))
    arts.append(("dense_apply_32", lowered))
    return arts


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=os.path.join("..", "artifacts"))
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    for name, lowered in build_artifacts():
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")


if __name__ == "__main__":
    main()
