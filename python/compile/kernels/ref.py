"""Pure-jnp oracle for the Pallas kernels (build-time correctness only).

The L1 hot-spot of a palm4MSA iteration is the projected-gradient core for
one factor S (paper Fig. 4 lines 5-6):

    E    = lam * L @ S @ R - A          (residual)
    G    = lam * L.T @ E @ R.T          (gradient)
    S'   = S - G / c                    (gradient step)

The projection (top-k + normalize) stays at L2 (jax.lax.top_k); the two
GEMM chains above dominate the flops and are what the Pallas kernel tiles.
"""

import jax.numpy as jnp


def palm_grad_step_ref(a, l, s, r, lam, c):
    """Reference PALM gradient step: S - (1/c) * lam * L^T (lam L S R - A) R^T."""
    e = lam * (l @ s @ r) - a
    g = lam * (l.T @ e @ r.T)
    return s - g / c


def faust_apply_ref(x, factors, lam):
    """Reference FAuST apply: lam * S_J ... S_1 @ x (factors rightmost first)."""
    y = x
    for f in factors:
        y = f @ y
    return lam * y


def proj_sp_ref(u, k):
    """Global top-k projection with unit-Frobenius normalization (Prop A.1)."""
    flat = u.reshape(-1)
    absu = jnp.abs(flat)
    # threshold = k-th largest magnitude
    thresh = jnp.sort(absu)[-k]
    mask = absu >= thresh
    kept = jnp.where(mask, flat, 0.0)
    norm = jnp.linalg.norm(kept)
    return jnp.where(norm > 0, kept / norm, kept).reshape(u.shape)
