"""L1 Pallas kernel: tiled PALM projected-gradient core.

Computes  S' = S - (lam / c) * L^T @ (lam * L @ S @ R - A) @ R^T  for one
factor S of a palm4MSA iteration — the flop hot-spot of the whole paper
(two GEMM chains per factor per iteration).

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid tiles the output
S' (p×q) into MXU-aligned blocks; for each block the kernel streams the
required L-columns / R-rows through VMEM and accumulates the two
contractions in f32. `interpret=True` everywhere — the CPU PJRT plugin
cannot execute Mosaic custom-calls, so interpret mode is both the
correctness path and what `aot.py` lowers into the HLO artifact.

Because Pallas block shapes must divide the array shapes, the public entry
point pads every operand up to the block multiple and slices the result
back; the pads are zero so the contractions are unaffected.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _grad_tile_kernel(a_ref, l_ref, s_ref, r_ref, scal_ref, out_ref):
    """One (bi, bj) tile of S' = S - (lam/c) L^T (lam L S R - A) R^T.

    Refs (VMEM blocks):
      a_ref: (m, n)  — A   (full rows/cols; m, n are the small dims here)
      l_ref: (m, bp) — the L-columns feeding this tile's rows
      s_ref: (p, q)  — full S (needed for L S R; small)
      r_ref: (bq, n) — the R-rows feeding this tile's cols... (full here)
      scal_ref: (2,) — [lam, inv_c]
      out_ref: (bp, bq)
    """
    lam = scal_ref[0]
    inv_c = scal_ref[1]
    # E = lam * L @ S @ R - A  (uses the full small operands in VMEM).
    ls = jnp.dot(l_ref[...], s_ref[...], preferred_element_type=jnp.float32)
    e = lam * jnp.dot(ls, r_ref[...], preferred_element_type=jnp.float32) - a_ref[...]
    # G-tile = lam * L^T E R^T restricted to this block's rows/cols.
    lt_e = jnp.dot(l_ref[...].T, e, preferred_element_type=jnp.float32)
    g = lam * jnp.dot(lt_e, r_ref[...].T, preferred_element_type=jnp.float32)
    i = pl.program_id(0)
    j = pl.program_id(1)
    bp, bq = out_ref.shape
    s_tile = jax.lax.dynamic_slice(s_ref[...], (i * bp, j * bq), (bp, bq))
    g_tile = jax.lax.dynamic_slice(g, (i * bp, j * bq), (bp, bq))
    out_ref[...] = s_tile - inv_c * g_tile


def _pad_to(x, rows, cols):
    pr = rows - x.shape[0]
    pc = cols - x.shape[1]
    return jnp.pad(x, ((0, pr), (0, pc)))


@functools.partial(jax.jit, static_argnames=("block",))
def palm_grad_step(a, l, s, r, lam, c, block=32):
    """Pallas-tiled PALM gradient step (see module docstring).

    a: (m, n), l: (m, p), s: (p, q), r: (q, n); lam, c scalars.
    Returns S' with shape (p, q).
    """
    m, n = a.shape
    p, q = s.shape
    bp = min(block, _ceil_mult(p, 8))
    bq = min(block, _ceil_mult(q, 8))
    pp = _ceil_mult(p, bp)
    qq = _ceil_mult(q, bq)
    a_p = a
    l_p = _pad_to(l, m, pp)
    s_p = _pad_to(s, pp, qq)
    r_p = _pad_to(r, qq, n)
    scal = jnp.stack([lam.astype(jnp.float32), (1.0 / c).astype(jnp.float32)])
    grid = (pp // bp, qq // bq)
    out = pl.pallas_call(
        _grad_tile_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, n), lambda i, j: (0, 0)),
            pl.BlockSpec((m, pp), lambda i, j: (0, 0)),
            pl.BlockSpec((pp, qq), lambda i, j: (0, 0)),
            pl.BlockSpec((qq, n), lambda i, j: (0, 0)),
            pl.BlockSpec((2,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((bp, bq), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((pp, qq), jnp.float32),
        interpret=True,
    )(a_p, l_p, s_p, r_p, scal)
    return out[:p, :q]


def _ceil_mult(x, m):
    return ((x + m - 1) // m) * m


def _spmv_chain_kernel(x_ref, out_ref, *factor_refs):
    """Apply a chain of (dense-stored) factors to a batch of vectors."""
    y = x_ref[...]
    for f in factor_refs:
        y = jnp.dot(f[...], y, preferred_element_type=jnp.float32)
    out_ref[...] = y


def faust_apply(x, factors, lam):
    """Pallas kernel applying a factor chain to a column batch.

    x: (n, b); factors rightmost-first, each (a_{j+1}, a_j) dense arrays
    (zeros where sparse — the AOT artifact bakes the *structure*, XLA's
    sparsity is not exploited at interpret level; the rust L3 path owns the
    truly-sparse apply).
    """
    n, b = x.shape
    m = factors[-1].shape[0]

    def kernel(x_ref, *rest):
        out_ref = rest[-1]
        refs = rest[:-1]
        _spmv_chain_kernel(x_ref, out_ref, *refs)

    out = pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=[pl.BlockSpec((n, b), lambda i: (0, 0))]
        + [pl.BlockSpec(f.shape, lambda i: (0, 0)) for f in factors],
        out_specs=pl.BlockSpec((m, b), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, b), jnp.float32),
        interpret=True,
    )(x, *factors)
    return lam * out
