"""L1 Pallas kernels (build-time only; lowered to HLO by ../aot.py)."""

from .palm_grad import faust_apply, palm_grad_step  # noqa: F401
from .ref import faust_apply_ref, palm_grad_step_ref, proj_sp_ref  # noqa: F401
