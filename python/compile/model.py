"""L2: the palm4MSA computation graph in JAX (build-time only).

Two AOT entry points, both for *fixed shapes* (one compiled executable per
model variant, loaded by rust/src/runtime):

- ``palm4msa_iteration``: one full palm4MSA sweep for a 2-factor split
  (the hierarchical algorithm's inner loop) — factor gradient steps via the
  L1 Pallas kernel, top-k projection + normalization, closed-form lambda.
- ``faust_apply_had32``: apply the 5-factor Hadamard-32 FAuST to a vector
  batch (the serving-path artifact the coordinator can execute via PJRT).

Python never runs at serving time: these functions exist to be lowered
once by aot.py.
"""

import jax
import jax.numpy as jnp

from .kernels.palm_grad import faust_apply, palm_grad_step


def proj_sp(u, k):
    """Top-k (global) projection + unit-Frobenius normalization (Prop A.1).

    argsort-based (stable, ties by index — matches the rust projection and
    lowers to plain HLO `sort`; `lax.top_k` emits a `topk` op that the
    xla_extension 0.5.1 text parser rejects).
    """
    flat = u.reshape(-1)
    idx = jnp.argsort(-jnp.abs(flat), stable=True)[:k]
    kept = jnp.zeros_like(flat).at[idx].set(flat[idx])
    norm = jnp.linalg.norm(kept)
    kept = jnp.where(norm > 0, kept / norm, kept)
    return kept.reshape(u.shape)


def _topk_mask_rows(u, k):
    """Boolean mask keeping the k largest |entries| of each row (stable
    index tie-break, argsort-based for old-HLO compatibility)."""
    idx = jnp.argsort(-jnp.abs(u), axis=1, stable=True)[:, :k]
    mask = jnp.zeros(u.shape, dtype=bool)
    rows = jnp.arange(u.shape[0])[:, None]
    return mask.at[rows, idx].set(True)


def proj_splincol(u, k):
    """FAuST-toolbox 'splincol': union of top-k-per-row and top-k-per-col
    supports, then unit-Frobenius normalization. The constraint the
    Hadamard reverse-engineering needs (global top-k is degenerate under
    the transform's all-equal magnitudes)."""
    mask = _topk_mask_rows(u, k) | _topk_mask_rows(u.T, k).T
    kept = jnp.where(mask, u, 0.0)
    norm = jnp.linalg.norm(kept)
    return jnp.where(norm > 0, kept / norm, kept)


def _spectral_norm_sq(m, iters=20):
    """Power iteration estimate of ||m||_2^2 (fixed iteration count so the
    lowered HLO is a static loop)."""
    v = jnp.ones((m.shape[1],), dtype=m.dtype) / jnp.sqrt(m.shape[1])

    def body(_, v):
        w = m @ v
        u = m.T @ w
        return u / (jnp.linalg.norm(u) + 1e-30)

    v = jax.lax.fori_loop(0, iters, body, v)
    return jnp.linalg.norm(m @ v) ** 2 / (jnp.linalg.norm(v) ** 2 + 1e-30)


def palm4msa_iteration(a, s, t, lam, proj_s, proj_t, alpha=1e-3):
    """One palm4MSA sweep for the 2-factor split A ~ lam * T @ S.

    s: (p, n) sparse factor, t: (m, p) residual; `proj_s`/`proj_t` are the
    projection operators onto their constraint sets. Returns (s', t', lam').
    """
    m, n = a.shape
    eye_n = jnp.eye(n, dtype=a.dtype)
    eye_m = jnp.eye(m, dtype=a.dtype)
    # --- update S: L = T, R = Id.
    c_s = (1.0 + alpha) * lam * lam * _spectral_norm_sq(t) + 1e-30
    s_stepped = palm_grad_step(a, t, s, eye_n, lam, c_s)
    s_new = proj_s(s_stepped)
    # --- update T: L = Id, R = S'.
    c_t = (1.0 + alpha) * lam * lam * _spectral_norm_sq(s_new) + 1e-30
    t_stepped = palm_grad_step(a, eye_m, t, s_new, lam, c_t)
    t_new = proj_t(t_stepped)
    # --- lambda: <A, T'S'> / ||T'S'||^2.
    a_hat = t_new @ s_new
    lam_new = jnp.sum(a * a_hat) / (jnp.sum(a_hat * a_hat) + 1e-30)
    return s_new, t_new, lam_new


def palm4msa_iteration_had32(a, s, t, lam):
    """Fixed-shape specialization for the Hadamard-32 split: splincol(2)
    on the butterfly factor, splincol(n/2) on the residual — the AOT
    artifact `palm_grad_step`."""
    n = 32
    return palm4msa_iteration(
        a,
        s,
        t,
        lam,
        proj_s=lambda u: proj_splincol(u, 2),
        proj_t=lambda u: proj_splincol(u, n // 2),
    )


def faust_apply_had32(x, f1, f2, f3, f4, f5):
    """Apply the 5-factor Hadamard-32 FAuST to x (32, b) via the L1 kernel."""
    return faust_apply(x, [f1, f2, f3, f4, f5], jnp.float32(1.0))
