#!/usr/bin/env python3
"""Gate machine-readable bench results against the committed baseline.

Usage:
    bench_gate.py <baseline.json> <BENCH_*.json> [<BENCH_*.json> ...]
    bench_gate.py --self-check

Each bench result file is the output of `faust::bench_util::BenchReport`
(`{"name": ..., "metrics": {...}}`). The baseline maps bench names to
per-metric rules:

    {"min": x}                  fail if measured < x        (ratios, flags)
    {"max": x}                  fail if measured > x        (error bounds)
    {"value": x, "tol_pct": p}  fail if measured > x*(1+p/100)
                                (wall-clock regression gate)

Keys starting with "_" are comments. Every way a gate can silently
disarm itself is a loud failure instead:

  - a metric named in the baseline but missing from the results (a bench
    silently dropping a gated metric is itself a regression);
  - a result file that does not exist or is not valid JSON (a bench that
    forgot `--json`, or crashed mid-write);
  - a result whose bench name has no baseline entry (a renamed bench
    would otherwise skip its own rules);
  - a rule naming no recognized bound key (a min/max/value typo would
    otherwise vacuously pass);
  - a result emitting a precision-suffixed metric (an f32/f64 path
    component, e.g. f32_apply_speedup or f64_mini_p99_us) that has no
    baseline rule — the mixed-precision serving tier must never grow an
    ungated metric;
  - a result emitting an online-learning metric (online_* prefix, e.g.
    online_tracking_rel_err) that has no baseline rule — the streaming
    factorization tier must never grow an ungated metric either;
  - a run in which nothing was checked at all.

`--self-check` runs a built-in pytest-free scenario suite (temp files,
exit-code assertions) so CI can verify the gate itself still gates.
Exits non-zero on any failure.
"""

import json
import os
import re
import sys
import tempfile

# A metric whose name carries an f32/f64 path component belongs to the
# mixed-precision serving tier and MUST be gated (matches f32_apply_speedup,
# f64_mini_p99_us, foo_f32 — not gemm512_tiled_speedup).
PRECISION_METRIC = re.compile(r"(^|_)f(32|64)(_|$)")

# A metric from the streaming-factorization tier (benches/online_drift.rs
# and friends emit only online_*-prefixed keys) MUST likewise be gated —
# an unbaselined online metric would let a drift-tracking regression ship
# silently.
ONLINE_METRIC = re.compile(r"^online_")


def check_metric(name, key, value, rule):
    """Return (ok, description) for one metric against one rule."""
    parts = []
    ok = True
    if "min" in rule:
        parts.append(f"min {rule['min']}")
        if value < rule["min"]:
            ok = False
    if "max" in rule:
        parts.append(f"max {rule['max']}")
        if value > rule["max"]:
            ok = False
    if "value" in rule:
        tol = rule.get("tol_pct", 25.0)
        ceiling = rule["value"] * (1.0 + tol / 100.0)
        parts.append(f"<= {rule['value']} +{tol}% = {ceiling:.4g}")
        if value > ceiling:
            ok = False
    if not parts:
        # A rule that names no recognized bound (min/max/value) is a
        # baseline typo that would otherwise silently disarm the gate.
        return False, f"{name}.{key} = {value:.6g}  (rule has no min/max/value bound)"
    return ok, f"{name}.{key} = {value:.6g}  ({', '.join(parts)})"


def main(argv):
    if len(argv) >= 2 and argv[1] == "--self-check":
        return self_check()
    if len(argv) < 3:
        print(__doc__, file=sys.stderr)
        return 2
    with open(argv[1]) as f:
        baseline = json.load(f)
    failures = []
    checked = 0
    for path in argv[2:]:
        try:
            with open(path) as f:
                data = json.load(f)
        except OSError as e:
            failures.append(f"{path}: unreadable bench results ({e})")
            print(f"[gate] FAIL {path}: unreadable ({e})")
            continue
        except ValueError as e:
            failures.append(f"{path}: invalid JSON ({e})")
            print(f"[gate] FAIL {path}: invalid JSON ({e})")
            continue
        name = data.get("name", "?")
        metrics = data.get("metrics", {})
        rules = baseline.get(name)
        if rules is None:
            # A renamed bench must not silently disarm its own gate.
            failures.append(f"{path}: no baseline entry for bench '{name}'")
            print(f"[gate] FAIL {path}: no baseline entry for '{name}'")
            continue
        for key, rule in rules.items():
            if key.startswith("_"):
                continue
            value = metrics.get(key)
            if value is None:
                failures.append(f"{name}.{key}: metric missing from {path}")
                print(f"[gate] FAIL {name}.{key}: missing from {path}")
                continue
            checked += 1
            ok, desc = check_metric(name, key, value, rule)
            print(f"[gate] {'ok  ' if ok else 'FAIL'} {desc}")
            if not ok:
                failures.append(desc)
        for key in sorted(metrics):
            if PRECISION_METRIC.search(key) and key not in rules:
                msg = f"{name}.{key}: precision-tier metric has no baseline rule"
                failures.append(msg)
                print(f"[gate] FAIL {msg}")
            elif ONLINE_METRIC.match(key) and key not in rules:
                msg = f"{name}.{key}: online-learning metric has no baseline rule"
                failures.append(msg)
                print(f"[gate] FAIL {msg}")
    if checked == 0 and not failures:
        print("[gate] nothing was checked — missing bench results?", file=sys.stderr)
        return 1
    if failures:
        print(f"\n[gate] {len(failures)} gate failure(s):", file=sys.stderr)
        for f_ in failures:
            print(f"  - {f_}", file=sys.stderr)
        return 1
    print(f"\n[gate] all {checked} gated metrics within baseline")
    return 0


def self_check():
    """Pytest-free scenario suite: every silent-pass path must fail loudly."""
    baseline = {
        "_comment": "self-check baseline",
        "bench_a": {
            "_note": "comment keys are skipped",
            "ratio": {"min": 1.25},
            "err": {"max": 1e-6},
            "wall_s": {"value": 10.0, "tol_pct": 25},
        },
    }

    def result(name, metrics):
        return {"name": name, "metrics": metrics}

    good = result("bench_a", {"ratio": 1.5, "err": 1e-9, "wall_s": 9.0})
    scenarios = [
        ("all metrics within bounds", good, 0),
        ("min violated", result("bench_a", {"ratio": 1.1, "err": 1e-9, "wall_s": 9.0}), 1),
        ("max violated", result("bench_a", {"ratio": 1.5, "err": 1e-3, "wall_s": 9.0}), 1),
        ("tol ceiling violated", result("bench_a", {"ratio": 1.5, "err": 1e-9, "wall_s": 13.0}), 1),
        ("gated metric missing from results", result("bench_a", {"ratio": 1.5, "err": 1e-9}), 1),
        ("bench renamed away from its baseline entry", result("bench_b", {"ratio": 1.5}), 1),
    ]
    # Percentile-band rules (serve_latency style): one rule carrying both
    # a min and a max must enforce BOTH sides — the min rejects a
    # degenerate ~0 measurement (broken latency pairing), the max is the
    # runner-noise-aware ceiling — and a shed-rate ceiling must trip.
    band_baseline = {
        "serve_bench": {
            "p99_us": {"min": 50.0, "max": 100000.0},
            "shed_rate": {"max": 0.05},
        },
    }
    band_scenarios = [
        ("p99 inside its band, shed under ceiling",
         result("serve_bench", {"p99_us": 850.0, "shed_rate": 0.001}), 0),
        ("p99 below the band min (degenerate measurement)",
         result("serve_bench", {"p99_us": 0.0, "shed_rate": 0.001}), 1),
        ("p99 above the band max (latency regression)",
         result("serve_bench", {"p99_us": 250000.0, "shed_rate": 0.001}), 1),
        ("shed rate over its ceiling",
         result("serve_bench", {"p99_us": 850.0, "shed_rate": 0.2}), 1),
    ]
    # Precision-tier metrics (ISSUE 7): any emitted metric with an
    # f32/f64 path component must have a baseline rule — gated when it
    # does, loud failure when it does not, and names that merely contain
    # digits (gemm512) must not trip the detector.
    prec_baseline = {
        "prec_bench": {
            "f32_apply_speedup": {"min": 1.0},
            "f64_mini_p99_us": {"min": 50.0, "max": 200000.0},
            "gemm512_tiled_speedup": {"min": 1.25},
        },
    }
    prec_scenarios = [
        ("every precision metric ruled",
         result("prec_bench", {"f32_apply_speedup": 1.6, "f64_mini_p99_us": 900.0,
                               "gemm512_tiled_speedup": 1.4}), 0),
        ("precision metric emitted with no baseline rule",
         result("prec_bench", {"f32_apply_speedup": 1.6, "f64_mini_p99_us": 900.0,
                               "gemm512_tiled_speedup": 1.4, "f32_max_rel_err": 1e-6}), 1),
        ("suffix-position precision component also caught",
         result("prec_bench", {"f32_apply_speedup": 1.6, "f64_mini_p99_us": 900.0,
                               "gemm512_tiled_speedup": 1.4, "speedup_f32": 1.6}), 1),
    ]
    # Warm-startup ceiling (ISSUE 8 recovery gate): the warm path has a
    # hard wall-clock ceiling AND an exact zero on re-factorization work.
    # A max-0 rule must treat any positive count as a failure (the gate's
    # "max" comparison has no tolerance), and blowing the ceiling or
    # running even one PALM iteration during restore must each trip
    # independently.
    recovery_baseline = {
        "recovery": {
            "warm_start_ms": {"max": 100.0},
            "warm_palm_iters": {"max": 0.0},
            "cold_palm_iters": {"min": 1.0},
        },
    }
    recovery_scenarios = [
        ("warm start under ceiling, zero palm iterations",
         result("recovery", {"warm_start_ms": 4.2, "warm_palm_iters": 0.0,
                             "cold_palm_iters": 600.0}), 0),
        ("warm start over the ceiling",
         result("recovery", {"warm_start_ms": 350.0, "warm_palm_iters": 0.0,
                             "cold_palm_iters": 600.0}), 1),
        ("warm restore re-ran the solver (one iteration is one too many)",
         result("recovery", {"warm_start_ms": 4.2, "warm_palm_iters": 1.0,
                             "cold_palm_iters": 600.0}), 1),
        ("degenerate cold run never factorized, warm gates vacuous",
         result("recovery", {"warm_start_ms": 4.2, "warm_palm_iters": 0.0,
                             "cold_palm_iters": 0.0}), 1),
    ]
    # Online-learning metrics (ISSUE 9): any emitted online_*-prefixed
    # metric must have a baseline rule — an unbaselined drift metric must
    # fail loudly, and the prefix must anchor at the start (a metric
    # merely *containing* "online" is not in the tier).
    online_baseline = {
        "online": {
            "online_tracking_rel_err": {"max": 0.25},
            "online_swaps": {"min": 3.0},
            "went_online_ms": {"max": 1e9},
        },
    }
    online_scenarios = [
        ("every online metric ruled",
         result("online", {"online_tracking_rel_err": 0.04, "online_swaps": 9.0,
                           "went_online_ms": 12.0}), 0),
        ("online metric emitted with no baseline rule",
         result("online", {"online_tracking_rel_err": 0.04, "online_swaps": 9.0,
                           "went_online_ms": 12.0, "online_flop_parity": 1.0}), 1),
        ("non-prefix 'online' substring is not in the tier",
         result("online", {"online_tracking_rel_err": 0.04, "online_swaps": 9.0,
                           "went_online_ms": 12.0, "extra_metric": 1.0}), 0),
    ]
    assert not PRECISION_METRIC.search("gemm512_tiled_speedup")
    assert PRECISION_METRIC.search("f32_apply_speedup")
    assert PRECISION_METRIC.search("speedup_f64")
    assert ONLINE_METRIC.match("online_tracking_rel_err")
    assert not ONLINE_METRIC.match("went_online_ms")
    # A rule whose bound key is misspelled must fail, not silently pass.
    typo_baseline = {"bench_a": {"ratio": {"mn": 1.25}}}
    ran = 0
    with tempfile.TemporaryDirectory() as td:
        base_path = os.path.join(td, "baseline.json")
        with open(base_path, "w") as f:
            json.dump(baseline, f)
        for desc, res, want in scenarios:
            res_path = os.path.join(td, "BENCH_x.json")
            with open(res_path, "w") as f:
                json.dump(res, f)
            got = main(["bench_gate.py", base_path, res_path])
            assert got == want, f"self-check '{desc}': exit {got}, wanted {want}"
            ran += 1

        band_path = os.path.join(td, "band_baseline.json")
        with open(band_path, "w") as f:
            json.dump(band_baseline, f)
        for desc, res, want in band_scenarios:
            res_path = os.path.join(td, "BENCH_band.json")
            with open(res_path, "w") as f:
                json.dump(res, f)
            got = main(["bench_gate.py", band_path, res_path])
            assert got == want, f"self-check '{desc}': exit {got}, wanted {want}"
            ran += 1

        prec_path = os.path.join(td, "prec_baseline.json")
        with open(prec_path, "w") as f:
            json.dump(prec_baseline, f)
        for desc, res, want in prec_scenarios:
            res_path = os.path.join(td, "BENCH_prec.json")
            with open(res_path, "w") as f:
                json.dump(res, f)
            got = main(["bench_gate.py", prec_path, res_path])
            assert got == want, f"self-check '{desc}': exit {got}, wanted {want}"
            ran += 1

        online_path = os.path.join(td, "online_baseline.json")
        with open(online_path, "w") as f:
            json.dump(online_baseline, f)
        for desc, res, want in online_scenarios:
            res_path = os.path.join(td, "BENCH_online.json")
            with open(res_path, "w") as f:
                json.dump(res, f)
            got = main(["bench_gate.py", online_path, res_path])
            assert got == want, f"self-check '{desc}': exit {got}, wanted {want}"
            ran += 1

        recovery_path = os.path.join(td, "recovery_baseline.json")
        with open(recovery_path, "w") as f:
            json.dump(recovery_baseline, f)
        for desc, res, want in recovery_scenarios:
            res_path = os.path.join(td, "BENCH_recovery.json")
            with open(res_path, "w") as f:
                json.dump(res, f)
            got = main(["bench_gate.py", recovery_path, res_path])
            assert got == want, f"self-check '{desc}': exit {got}, wanted {want}"
            ran += 1

        typo_path = os.path.join(td, "typo.json")
        with open(typo_path, "w") as f:
            json.dump(typo_baseline, f)
        res_path = os.path.join(td, "BENCH_x.json")
        with open(res_path, "w") as f:
            json.dump(good, f)
        got = main(["bench_gate.py", typo_path, res_path])
        assert got == 1, f"self-check 'misspelled bound key': exit {got}, wanted 1"
        ran += 1

        # A result file that does not exist (bench forgot --json).
        got = main(["bench_gate.py", base_path, os.path.join(td, "BENCH_missing.json")])
        assert got == 1, f"self-check 'missing results file': exit {got}, wanted 1"
        ran += 1

        # A result file that is not JSON (crashed mid-write).
        bad_path = os.path.join(td, "BENCH_bad.json")
        with open(bad_path, "w") as f:
            f.write('{"name": "bench_a", "metrics": {')
        got = main(["bench_gate.py", base_path, bad_path])
        assert got == 1, f"self-check 'invalid JSON': exit {got}, wanted 1"
        ran += 1

        # A baseline entry with only comment keys checks nothing -> fail.
        empty_base = os.path.join(td, "empty.json")
        with open(empty_base, "w") as f:
            json.dump({"bench_a": {"_only": "comments"}}, f)
        res_path = os.path.join(td, "BENCH_x.json")
        with open(res_path, "w") as f:
            json.dump(good, f)
        got = main(["bench_gate.py", empty_base, res_path])
        assert got == 1, f"self-check 'nothing checked': exit {got}, wanted 1"
        ran += 1

        # Usage error still reports distinctly.
        got = main(["bench_gate.py"])
        assert got == 2, f"self-check 'usage': exit {got}, wanted 2"
        ran += 1

    print(f"\n[gate] self-check: all {ran} scenarios behaved")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
