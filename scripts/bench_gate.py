#!/usr/bin/env python3
"""Gate machine-readable bench results against the committed baseline.

Usage:
    bench_gate.py <baseline.json> <BENCH_*.json> [<BENCH_*.json> ...]

Each bench result file is the output of `faust::bench_util::BenchReport`
(`{"name": ..., "metrics": {...}}`). The baseline maps bench names to
per-metric rules:

    {"min": x}                  fail if measured < x        (ratios, flags)
    {"max": x}                  fail if measured > x        (error bounds)
    {"value": x, "tol_pct": p}  fail if measured > x*(1+p/100)
                                (wall-clock regression gate)

Keys starting with "_" are comments. A metric named in the baseline but
missing from the results fails the gate (a bench silently dropping a
gated metric is itself a regression). Exits non-zero on any failure, and
also when nothing was checked at all.
"""

import json
import sys


def check_metric(name, key, value, rule):
    """Return (ok, description) for one metric against one rule."""
    parts = []
    ok = True
    if "min" in rule:
        parts.append(f"min {rule['min']}")
        if value < rule["min"]:
            ok = False
    if "max" in rule:
        parts.append(f"max {rule['max']}")
        if value > rule["max"]:
            ok = False
    if "value" in rule:
        tol = rule.get("tol_pct", 25.0)
        ceiling = rule["value"] * (1.0 + tol / 100.0)
        parts.append(f"<= {rule['value']} +{tol}% = {ceiling:.4g}")
        if value > ceiling:
            ok = False
    bound = ", ".join(parts) if parts else "no bounds?!"
    return ok, f"{name}.{key} = {value:.6g}  ({bound})"


def main(argv):
    if len(argv) < 3:
        print(__doc__, file=sys.stderr)
        return 2
    with open(argv[1]) as f:
        baseline = json.load(f)
    failures = []
    checked = 0
    for path in argv[2:]:
        with open(path) as f:
            data = json.load(f)
        name = data.get("name", "?")
        metrics = data.get("metrics", {})
        rules = baseline.get(name)
        if rules is None:
            print(f"[gate] {path}: no baseline entry for '{name}' — skipped")
            continue
        for key, rule in rules.items():
            if key.startswith("_"):
                continue
            value = metrics.get(key)
            if value is None:
                failures.append(f"{name}.{key}: metric missing from {path}")
                print(f"[gate] FAIL {name}.{key}: missing from {path}")
                continue
            checked += 1
            ok, desc = check_metric(name, key, value, rule)
            print(f"[gate] {'ok  ' if ok else 'FAIL'} {desc}")
            if not ok:
                failures.append(desc)
    if checked == 0:
        print("[gate] nothing was checked — missing bench results?", file=sys.stderr)
        return 1
    if failures:
        print(f"\n[gate] {len(failures)} gate failure(s):", file=sys.stderr)
        for f_ in failures:
            print(f"  - {f_}", file=sys.stderr)
        return 1
    print(f"\n[gate] all {checked} gated metrics within baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
