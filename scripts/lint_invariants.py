#!/usr/bin/env python3
"""Repo-invariant lint gate: determinism hazards the compiler cannot see.

Usage:
    lint_invariants.py [--root REPO_ROOT]
    lint_invariants.py --self-check

Two rules, both downstream of the crate's determinism contract (bitwise
identical serving results across thread counts, restarts, and machines —
see docs/ARCHITECTURE.md, "verification layers"):

Rule A — no nondeterministic hash iteration in serving/dispatch code
    (`rust/src/coordinator/`, `rust/src/server/`). `HashMap`/`HashSet`
    iteration order is randomized per process (`RandomState`), so any
    `.iter()/.keys()/.values()/.drain()/.into_iter()` or `for .. in` over
    a hash container in those modules makes batch flush order, shard
    placement, float accumulation order, or wire responses depend on the
    seed of the process that happens to serve the request. Point lookups
    (`get`/`insert`/`remove`) are fine — only *iteration* is flagged.
    The checker tracks hash-typed names through field/param/let
    declarations, through `RwLock<HashMap<..>>`-style wrappers, and
    through lock guards bound from `.read()`/`.write()`/`.lock()` on a
    hash-typed field, and it follows method chains across a line break.
    A site that is genuinely order-insensitive (e.g. collect-then-sort)
    is waived with a `// det-ok: <why>` comment on the same line or the
    line directly above.

Rule B — no wall-clock reads in kernel code (`rust/src/engine/kernel.rs`).
    `Instant::now` / `SystemTime` inside the microkernel layer would mean
    math dispatch or tiling decisions can depend on timing, which breaks
    the bitwise thread-invariance contract the kernel proptests pin.
    Not waivable: timing belongs in the callers (pool, benches, metrics).

`--self-check` runs a built-in pytest-free scenario suite (temp trees,
exit-code assertions) so CI can verify the gate itself still gates.
Exits non-zero on any violation.
"""

import os
import re
import sys
import tempfile

# Directories (relative to the repo root) under Rule A's scope.
SCOPED_DIRS = [
    os.path.join("rust", "src", "coordinator"),
    os.path.join("rust", "src", "server"),
]

# File under Rule B's scope.
KERNEL_FILE = os.path.join("rust", "src", "engine", "kernel.rs")

# A declaration that gives a name a hash-container type. Three shapes:
# `let x = HashMap::new()` / `let x: HashMap<..> = ..` / `field: HashMap<..>`
# (the last also catches fn params and `RwLock<HashMap<..>>` wrappers,
# since the type text merely has to *contain* the token).
LET_FROM_CTOR = re.compile(
    r"\blet\s+(?:mut\s+)?(\w+)\s*(?::[^=;]*)?=\s*[\w:]*\b(?:HashMap|HashSet)\b"
)
LET_WITH_TYPE = re.compile(r"\blet\s+(?:mut\s+)?(\w+)\s*:\s*[^=;]*\b(?:HashMap|HashSet)\b")
FIELD_OR_PARAM = re.compile(r"\b(\w+)\s*:\s*&?[\w:<>,'\s]*\b(?:HashMap|HashSet)\b")

# A lock guard bound from a hash-typed field inherits the hash type:
# `let g = self.ops.read().unwrap();`
GUARD_BIND = re.compile(
    r"\blet\s+(?:mut\s+)?(\w+)\s*=\s*(?:self\s*\.\s*)?(\w+)\s*\.\s*(?:read|write|lock)\s*\("
)

ITER_METHODS = r"(?:iter|iter_mut|keys|values|values_mut|drain|into_iter)"

WALL_CLOCK = re.compile(r"\bInstant\s*::\s*now\b|\bSystemTime\b")

WAIVER = "det-ok"


def strip_comments(line):
    """Drop `// ...` so doc text mentioning HashMap never declares one."""
    cut = line.find("//")
    return line if cut < 0 else line[:cut]


def hash_names_of(lines):
    """Names declared hash-typed in this file (incl. lock guards of them)."""
    names = set()
    for raw in lines:
        code = strip_comments(raw)
        for pat in (LET_FROM_CTOR, LET_WITH_TYPE, FIELD_OR_PARAM):
            for m in pat.finditer(code):
                names.add(m.group(1))
    # Guard binding is a second pass so a guard of a field declared later
    # in the file (impl above struct) is still caught.
    for raw in lines:
        m = GUARD_BIND.search(strip_comments(raw))
        if m and m.group(2) in names:
            names.add(m.group(1))
    return names


def waived(lines, first, last):
    """`// det-ok:` anywhere on the flagged lines or the line above.

    A chain split across lines (`map\\n    .iter()`) spans `first..last`;
    the waiver may sit on any of them (typically the `.iter()` line).
    """
    for ln in range(first - 1, last + 1):
        if 1 <= ln <= len(lines) and WAIVER in lines[ln - 1]:
            return True
    return False


def check_hash_iteration(path, text):
    """Rule A violations in one file: list of (lineno, description)."""
    lines = text.splitlines()
    names = hash_names_of(lines)
    if not names:
        return []
    # Scan comment-stripped text as one string: `\s` crosses the newline,
    # so a chain split as `map\n    .iter()` is still one match.
    clean = "\n".join(strip_comments(l) for l in lines)
    alt = "|".join(re.escape(n) for n in sorted(names))
    # A tracked name counts only as a plain binding or a `self.` field —
    # `other.ops` is some *other* type's field that merely shares the
    # name, so it must not inherit the hash classification.
    recv = r"(?:\bself\s*\.\s*|(?<![.\w]))"
    method_use = re.compile(recv + r"(" + alt + r")\b\s*\.\s*" + ITER_METHODS + r"\s*\(")
    for_use = re.compile(
        r"\bfor\s+[\w\s,()&]+?\bin\s+&?(?:mut\s+)?" + recv + r"(" + alt + r")\b(?!\s*\.)"
    )
    out = []
    for pat, what in ((method_use, "iterated"), (for_use, "looped over")):
        for m in pat.finditer(clean):
            lineno = clean.count("\n", 0, m.start(1)) + 1
            endline = clean.count("\n", 0, m.end()) + 1
            if waived(lines, lineno, endline):
                continue
            out.append(
                (
                    lineno,
                    f"hash container `{m.group(1)}` {what} in serving code "
                    "(RandomState order; sort first or waive with `// det-ok:`)",
                )
            )
    return sorted(set(out))


def check_wall_clock(path, text):
    """Rule B violations in the kernel file: list of (lineno, description)."""
    out = []
    for i, raw in enumerate(text.splitlines(), 1):
        if WALL_CLOCK.search(strip_comments(raw)):
            out.append((i, "wall-clock read in kernel code (not waivable)"))
    return out


def scoped_files(root):
    for d in SCOPED_DIRS:
        base = os.path.join(root, d)
        for dirpath, _, files in os.walk(base):
            for f in sorted(files):
                if f.endswith(".rs"):
                    yield os.path.join(dirpath, f)


def main(argv):
    if "--self-check" in argv[1:]:
        return self_check()
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    if "--root" in argv[1:]:
        root = argv[argv.index("--root") + 1]
    violations = []
    checked = 0
    for path in scoped_files(root):
        rel = os.path.relpath(path, root)
        try:
            with open(path) as f:
                text = f.read()
        except OSError as e:
            violations.append(f"{rel}: unreadable ({e})")
            continue
        checked += 1
        for lineno, desc in check_hash_iteration(rel, text):
            violations.append(f"{rel}:{lineno}: {desc}")
    kpath = os.path.join(root, KERNEL_FILE)
    try:
        with open(kpath) as f:
            ktext = f.read()
        checked += 1
        for lineno, desc in check_wall_clock(KERNEL_FILE, ktext):
            violations.append(f"{KERNEL_FILE}:{lineno}: {desc}")
    except OSError as e:
        violations.append(f"{KERNEL_FILE}: unreadable ({e})")
    if checked == 0:
        # An empty scope means the gate is pointed at the wrong tree.
        print("[lint] nothing was checked — wrong --root?", file=sys.stderr)
        return 1
    if violations:
        print(f"[lint] {len(violations)} invariant violation(s):", file=sys.stderr)
        for v in violations:
            print(f"  - {v}", file=sys.stderr)
        return 1
    print(f"[lint] {checked} files clean (hash-iteration + wall-clock invariants)")
    return 0


def self_check():
    """Pytest-free scenario suite: every hazard shape must be caught."""
    coord = os.path.join("rust", "src", "coordinator")
    engine = os.path.join("rust", "src", "engine")

    # Each scenario: (description, {relpath: contents}, wanted exit code).
    scenarios = [
        (
            "Vec iteration and hash point-lookups are clean",
            {
                os.path.join(coord, "a.rs"): (
                    "struct B { pending: Vec<(K, E)>, idx: HashMap<String, usize> }\n"
                    "fn f(b: &B) {\n"
                    "    for (k, e) in b.pending.iter() { use_(k, e); }\n"
                    "    let one = b.idx.get(\"x\");\n"
                    "    b.idx.insert(\"y\".into(), 1);\n"
                    "}\n"
                ),
            },
            0,
        ),
        (
            "hash field iterated through self",
            {
                os.path.join(coord, "a.rs"): (
                    "struct R { ops: HashMap<String, Entry> }\n"
                    "impl R { fn all(&self) { for (k, v) in self.ops.iter() { go(k, v); } } }\n"
                ),
            },
            1,
        ),
        (
            "let-bound HashMap keys() flagged",
            {
                os.path.join(coord, "a.rs"): (
                    "fn f() {\n"
                    "    let m = HashMap::new();\n"
                    "    for k in m.keys() { go(k); }\n"
                    "}\n"
                ),
            },
            1,
        ),
        (
            "chain split across a line break still flagged",
            {
                os.path.join(coord, "a.rs"): (
                    "fn f(m: &HashMap<String, f64>) {\n"
                    "    let total: f64 = m\n"
                    "        .values()\n"
                    "        .sum();\n"
                    "}\n"
                ),
            },
            1,
        ),
        (
            "det-ok waiver on the same line",
            {
                os.path.join(coord, "a.rs"): (
                    "fn f(m: &HashMap<String, f64>) {\n"
                    "    let mut v: Vec<_> = m.iter().collect(); // det-ok: sorted below\n"
                    "    v.sort_by(|a, b| a.0.cmp(b.0));\n"
                    "}\n"
                ),
            },
            0,
        ),
        (
            "det-ok waiver on the line above",
            {
                os.path.join(coord, "a.rs"): (
                    "fn f(m: &HashMap<String, f64>) {\n"
                    "    // det-ok: sorted below\n"
                    "    let mut v: Vec<_> = m.iter().collect();\n"
                    "    v.sort_by(|a, b| a.0.cmp(b.0));\n"
                    "}\n"
                ),
            },
            0,
        ),
        (
            "det-ok waiver on the .iter() line of a split chain",
            {
                os.path.join(coord, "a.rs"): (
                    "fn f(m: &HashMap<String, f64>) {\n"
                    "    let mut v: Vec<_> = m\n"
                    "        .iter() // det-ok: sorted below\n"
                    "        .collect();\n"
                    "    v.sort_by(|a, b| a.0.cmp(b.0));\n"
                    "}\n"
                ),
            },
            0,
        ),
        (
            "another struct's same-named Vec field is not the hash field",
            {
                os.path.join(coord, "a.rs"): (
                    "struct R { ops: RwLock<HashMap<String, Entry>> }\n"
                    "struct Loaded { ops: Vec<StoredOp> }\n"
                    "fn f(loaded: &Loaded) -> u64 {\n"
                    "    loaded.ops.iter().map(|s| s.epoch).max().unwrap_or(0)\n"
                    "}\n"
                ),
            },
            0,
        ),
        (
            "RwLock guard of a hash field iterated",
            {
                os.path.join(coord, "a.rs"): (
                    "struct R { ops: RwLock<HashMap<String, Entry>> }\n"
                    "impl R {\n"
                    "    fn place(&self) {\n"
                    "        let g = self.ops.read().unwrap();\n"
                    "        for (k, v) in g.iter() { go(k, v); }\n"
                    "    }\n"
                    "}\n"
                ),
            },
            1,
        ),
        (
            "`for .. in &map` without an iter() call flagged",
            {
                os.path.join(coord, "a.rs"): (
                    "fn f(seen: HashSet<u64>) {\n"
                    "    for s in &seen { go(s); }\n"
                    "}\n"
                ),
            },
            1,
        ),
        (
            "hash iteration outside the scoped dirs is not Rule A's business",
            {
                os.path.join(engine, "plan.rs"): (
                    "fn f(m: &HashMap<String, f64>) {\n"
                    "    for k in m.keys() { go(k); }\n"
                    "}\n"
                ),
            },
            0,
        ),
        (
            "doc comment mentioning HashMap declares nothing",
            {
                os.path.join(coord, "a.rs"): (
                    "/// Unlike a HashMap, flush order here is insertion order.\n"
                    "struct B { pending: Vec<(K, E)> }\n"
                    "fn f(b: &B) { for e in b.pending.iter() { go(e); } }\n"
                ),
            },
            0,
        ),
        (
            "wall-clock read in kernel code flagged",
            {
                os.path.join(engine, "kernel.rs"): (
                    "fn detect() -> SimdLevel {\n"
                    "    let t0 = Instant::now();\n"
                    "    SimdLevel::Portable\n"
                    "}\n"
                ),
            },
            1,
        ),
        (
            "SystemTime in kernel code flagged even in cfg'd code",
            {
                os.path.join(engine, "kernel.rs"): (
                    "#[cfg(feature = \"x\")]\n"
                    "fn stamp() -> std::time::SystemTime { std::time::SystemTime::now() }\n"
                ),
            },
            1,
        ),
        (
            "kernel mentioning Instant only in a comment is clean",
            {
                os.path.join(engine, "kernel.rs"): (
                    "// Timing (Instant::now) belongs in the pool, never here.\n"
                    "pub fn lane_width() -> usize { 4 }\n"
                ),
            },
            0,
        ),
    ]

    ran = 0
    for desc, files, want in scenarios:
        with tempfile.TemporaryDirectory() as td:
            # Every scenario tree carries a clean kernel file unless the
            # scenario supplies its own (the real run always checks it).
            defaults = {os.path.join(engine, "kernel.rs"): "pub fn lane_width() -> usize { 4 }\n"}
            defaults.update(files)
            for rel, contents in defaults.items():
                path = os.path.join(td, rel)
                os.makedirs(os.path.dirname(path), exist_ok=True)
                with open(path, "w") as f:
                    f.write(contents)
            got = main(["lint_invariants.py", "--root", td])
            assert got == want, f"self-check '{desc}': exit {got}, wanted {want}"
            ran += 1

    # An empty tree must fail loudly, not vacuously pass.
    with tempfile.TemporaryDirectory() as td:
        got = main(["lint_invariants.py", "--root", td])
        assert got == 1, f"self-check 'empty tree': exit {got}, wanted 1"
        ran += 1

    print(f"\n[lint] self-check: all {ran} scenarios behaved")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
